//! Fig. 2 — phase-transition diagrams.
//!
//! Empirical success rate (`SSE_method ≤ 1.2·SSE_kmeans`, k-means best of 5)
//! as a function of the measurement budget `m/(nK)` and either the sample
//! dimension `n` (Fig. 2a: K = 2, means ±1⃗, cov `(n/20)·Id`) or the number
//! of clusters `K` (Fig. 2b: n = 5, means random in `{±1}ⁿ`). The paper's
//! headline: both CKM and QCKM transition at a constant `m/(nK)`, QCKM
//! needing ~1.13× (vs n) to ~1.23× (vs K) more measurements.

use super::common::{ascii_heatmap, run_method_once, transition_ratio, MethodRun};
use crate::clompr::ClOmprParams;
use crate::data::gaussian_mixture_pm1;
use crate::decoder::DecoderSpec;
use crate::frequency::{FrequencyLaw, SigmaHeuristic};
use crate::kmeans::{kmeans, KMeansParams};
use crate::method::MethodSpec;
use crate::metrics::is_success;
use crate::parallel::{self, Parallelism};
use crate::rng::Rng;

/// Which panel of Fig. 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fig2Variant {
    /// Fig. 2a: sweep dimension n at K = 2.
    VaryDimension,
    /// Fig. 2b: sweep cluster count K at n = 5.
    VaryClusters,
}

/// Grid configuration.
#[derive(Clone, Debug)]
pub struct Fig2Config {
    pub variant: Fig2Variant,
    /// Swept values of n (2a) or K (2b).
    pub values: Vec<usize>,
    /// Swept measurement ratios m/(nK) (frequencies per parameter).
    pub ratios: Vec<f64>,
    /// Trials per cell.
    pub trials: usize,
    /// Samples per trial dataset.
    pub n_samples: usize,
    pub methods: Vec<MethodSpec>,
    pub sigma: SigmaHeuristic,
    pub law: FrequencyLaw,
    pub seed: u64,
    pub decoder: ClOmprParams,
    /// The decoding algorithm every trial routes through
    /// ([`crate::decoder`] registry spec; `decoder` above is its base
    /// tuning). Default `clompr` = the paper's CL-OMPR.
    pub decoder_spec: DecoderSpec,
    /// Threads for the (value × trial) fan-out (0 = all cores). Per-trial
    /// RNG substreams make the grid bit-for-bit identical at any setting.
    pub threads: usize,
    /// Sketch each trial through the out-of-core streaming fold
    /// ([`crate::stream`]) instead of the in-memory encode — the streamed
    /// variant of the figure (`qckm experiment fig2a --streamed`).
    pub streamed: bool,
}

impl Fig2Config {
    /// The reduced default grid (minutes, not hours). `--full` in the CLI
    /// switches to the paper-scale grid.
    pub fn quick(variant: Fig2Variant) -> Self {
        let values = match variant {
            Fig2Variant::VaryDimension => vec![2, 4, 8, 16, 24],
            Fig2Variant::VaryClusters => vec![2, 3, 4, 5, 6],
        };
        let ratios = match variant {
            Fig2Variant::VaryDimension => vec![0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0],
            // Larger K transitions later in this implementation (see
            // EXPERIMENTS.md §Calibration) — extend the ratio axis.
            Fig2Variant::VaryClusters => vec![1.0, 1.5, 2.0, 3.0, 4.0, 6.0, 8.0, 10.0],
        };
        Self {
            variant,
            values,
            ratios,
            trials: 12,
            n_samples: 4096,
            methods: vec![
                MethodSpec::parse("ckm").expect("registry spec"),
                MethodSpec::parse("qckm").expect("registry spec"),
            ],
            sigma: SigmaHeuristic::default(),
            law: FrequencyLaw::AdaptedRadius,
            seed: 0x20180619, // the paper's date
            decoder: ClOmprParams::default(),
            decoder_spec: DecoderSpec::default(),
            threads: 0,
            streamed: false,
        }
    }

    /// Paper-scale grid (N = 10⁴, 100 trials).
    pub fn full(variant: Fig2Variant) -> Self {
        let mut cfg = Self::quick(variant);
        cfg.values = match variant {
            Fig2Variant::VaryDimension => vec![2, 3, 4, 6, 8, 12, 16, 24, 32, 48],
            Fig2Variant::VaryClusters => vec![2, 3, 4, 5, 6, 7, 8, 9, 10],
        };
        cfg.ratios = vec![
            0.3, 0.5, 0.75, 1.0, 1.5, 2.0, 2.5, 3.0, 4.0, 5.0, 6.5, 8.0, 10.0, 13.0,
        ];
        cfg.trials = 100;
        cfg.n_samples = 10_000;
        cfg
    }

    fn nk(&self, value: usize) -> (usize, usize) {
        match self.variant {
            Fig2Variant::VaryDimension => (value, 2),
            Fig2Variant::VaryClusters => (5, value),
        }
    }
}

/// Success-rate grids per method plus the derived transition lines.
#[derive(Clone, Debug)]
pub struct Fig2Result {
    pub config_desc: String,
    /// `success[method_idx][value_idx][ratio_idx]` ∈ [0, 1].
    pub success: Vec<Vec<Vec<f64>>>,
    pub methods: Vec<MethodSpec>,
    pub values: Vec<usize>,
    pub ratios: Vec<f64>,
    /// ≥50% transition ratio per method per value (None = never).
    pub transitions: Vec<Vec<Option<f64>>>,
    /// Mean QCKM/CKM transition-ratio factor (the paper's 1.13 / 1.23).
    pub qckm_over_ckm: Option<f64>,
}

/// Run the grid. Prints nothing; see [`Fig2Result::render`].
///
/// The (value × trial) cells fan out across `cfg.threads` workers; each
/// trial derives its own RNG substream from the seed, so the grid is
/// reproducible and bit-for-bit identical at any thread count (results are
/// merged in trial order — see [`crate::parallel`]).
pub fn run_fig2(cfg: &Fig2Config) -> Fig2Result {
    let n_methods = cfg.methods.len();
    let mut success = vec![vec![vec![0.0; cfg.ratios.len()]; cfg.values.len()]; n_methods];

    // One job per (value, trial); each returns success flags [method][ratio].
    let jobs = cfg.values.len() * cfg.trials;
    let par = Parallelism::fixed(cfg.threads);
    let flags: Vec<Vec<Vec<bool>>> = parallel::par_map(jobs, &par, |job| {
        let vi = job / cfg.trials;
        let trial = job % cfg.trials;
        let (n, k) = cfg.nk(cfg.values[vi]);
        // Per-trial RNG substream → trials are independent and the whole
        // grid is reproducible from the seed.
        let mut rng = Rng::new(cfg.seed)
            .substream(vi as u64)
            .substream(trial as u64);
        let data = gaussian_mixture_pm1(cfg.n_samples, n, k, &mut rng);
        let sigma = cfg.sigma.resolve(&data.points, &mut rng);
        // Shared baseline: best of 5 k-means runs (paper's criterion).
        let km = kmeans(
            &data.points,
            k,
            &KMeansParams {
                replicates: 5,
                ..Default::default()
            },
            &mut rng,
        );
        cfg.methods
            .iter()
            .map(|method| {
                cfg.ratios
                    .iter()
                    .map(|&ratio| {
                        let m = ((ratio * (n * k) as f64).round() as usize).max(2);
                        let run = MethodRun {
                            method: method.clone(),
                            m,
                            replicates: 1,
                            sigma,
                            law: cfg.law,
                            params: cfg.decoder.clone(),
                            decoder: cfg.decoder_spec.clone(),
                            streamed: cfg.streamed,
                        };
                        let out = run_method_once(&run, &data.points, None, k, &mut rng);
                        is_success(out.sse, km.sse)
                    })
                    .collect()
            })
            .collect()
    });

    // Ordered merge of the per-trial flags into success rates.
    for (job, trial_flags) in flags.iter().enumerate() {
        let vi = job / cfg.trials;
        for (mi, row) in trial_flags.iter().enumerate() {
            for (ri, &hit) in row.iter().enumerate() {
                if hit {
                    success[mi][vi][ri] += 1.0;
                }
            }
        }
    }
    for grid in success.iter_mut() {
        for row in grid.iter_mut() {
            for v in row.iter_mut() {
                *v /= cfg.trials as f64;
            }
        }
    }

    // Transition lines + QCKM/CKM factor.
    let mut transitions = Vec::with_capacity(n_methods);
    for mi in 0..n_methods {
        transitions.push(
            (0..cfg.values.len())
                .map(|vi| transition_ratio(&cfg.ratios, &success[mi][vi]))
                .collect::<Vec<_>>(),
        );
    }
    let qckm_over_ckm = factor_between(&cfg.methods, &transitions, "qckm", "ckm");

    Fig2Result {
        config_desc: format!(
            "{:?}: values {:?}, ratios {:?}, {} trials, N = {}, decoder {}{}",
            cfg.variant,
            cfg.values,
            cfg.ratios,
            cfg.trials,
            cfg.n_samples,
            cfg.decoder_spec.canonical(),
            if cfg.streamed { ", streamed sketch" } else { "" }
        ),
        success,
        methods: cfg.methods.clone(),
        values: cfg.values.clone(),
        ratios: cfg.ratios.clone(),
        transitions,
        qckm_over_ckm,
    }
}

fn factor_between(
    methods: &[MethodSpec],
    transitions: &[Vec<Option<f64>>],
    num: &str,
    den: &str,
) -> Option<f64> {
    let ni = methods.iter().position(|m| m.canonical() == num)?;
    let di = methods.iter().position(|m| m.canonical() == den)?;
    let mut ratios = Vec::new();
    for (a, b) in transitions[ni].iter().zip(&transitions[di]) {
        if let (Some(a), Some(b)) = (a, b) {
            if *b > 0.0 {
                ratios.push(a / b);
            }
        }
    }
    if ratios.is_empty() {
        None
    } else {
        Some(ratios.iter().sum::<f64>() / ratios.len() as f64)
    }
}

impl Fig2Result {
    /// Render the heatmaps + transition lines as the terminal "figure".
    pub fn render(&self) -> String {
        let mut out = format!("== Fig. 2 phase transition ==\n{}\n\n", self.config_desc);
        let value_label = "n or K";
        for (mi, method) in self.methods.iter().enumerate() {
            out.push_str(&format!("--- {} success rate ---\n", method.canonical()));
            let rows: Vec<String> = self
                .values
                .iter()
                .map(|v| format!("{value_label}={v}"))
                .collect();
            out.push_str(&ascii_heatmap(&rows, &self.ratios, &self.success[mi]));
            out.push_str("  >=50% transition at m/(nK): ");
            for t in &self.transitions[mi] {
                match t {
                    Some(r) => out.push_str(&format!("{r:>6.2}")),
                    None => out.push_str("     -"),
                }
            }
            out.push_str("\n\n");
        }
        if let Some(f) = self.qckm_over_ckm {
            out.push_str(&format!(
                "QCKM needs {f:.2}x the measurements of CKM at the >=50% transition \
                 (paper: ~1.13x vs n, ~1.23x vs K)\n"
            ));
        } else {
            out.push_str("QCKM/CKM factor: not measurable on this grid\n");
        }
        out
    }
}
