//! Fast smoke tests of the experiment harnesses (tiny grids — the real
//! grids run via the CLI and are recorded in EXPERIMENTS.md).

use super::*;
use crate::frequency::SigmaHeuristic;
use crate::method::MethodSpec;

#[test]
fn fig2_tiny_grid_runs_and_orders_sensibly() {
    let mut cfg = Fig2Config::quick(Fig2Variant::VaryDimension);
    cfg.values = vec![4];
    cfg.ratios = vec![0.25, 6.0];
    cfg.trials = 3;
    cfg.n_samples = 800;
    let res = run_fig2(&cfg);
    assert_eq!(res.success.len(), 2); // two methods
    assert_eq!(res.success[0].len(), 1);
    assert_eq!(res.success[0][0].len(), 2);
    for mi in 0..2 {
        for v in &res.success[mi][0] {
            assert!((0.0..=1.0).contains(v));
        }
        // More measurements must not be (grossly) worse.
        assert!(
            res.success[mi][0][1] >= res.success[mi][0][0] - 0.34,
            "success not roughly monotone for method {mi}: {:?}",
            res.success[mi][0]
        );
    }
    let txt = res.render();
    assert!(txt.contains("Fig. 2"));
    assert!(txt.contains("ckm"));
}

#[test]
fn fig2b_variant_grid_shapes() {
    let mut cfg = Fig2Config::quick(Fig2Variant::VaryClusters);
    cfg.values = vec![2, 3];
    cfg.ratios = vec![4.0];
    cfg.trials = 2;
    cfg.n_samples = 600;
    cfg.methods = vec![MethodSpec::parse("qckm").unwrap()];
    let res = run_fig2(&cfg);
    assert_eq!(res.success.len(), 1);
    assert_eq!(res.success[0].len(), 2);
    assert!(res.qckm_over_ckm.is_none()); // no CKM arm
}

#[test]
fn fig3_tiny_runs_and_renders() {
    let mut cfg = Fig3Config::quick();
    cfg.n_samples = 1500;
    cfg.m = 150;
    cfg.k = 4;
    cfg.trials = 2;
    cfg.replicate_levels = vec![1];
    let res = run_fig3(&cfg);
    assert_eq!(res.rows.len(), 3); // kmeans, ckm, qckm at one level
    assert_eq!(res.sse_per_n.len(), 3);
    for &(mean, std) in &res.sse_per_n {
        assert!(mean > 0.0 && std >= 0.0);
    }
    for &(ari, _) in &res.ari {
        assert!((-0.5..=1.0).contains(&ari));
    }
    let txt = res.render();
    assert!(txt.contains("k-means x1"));
    assert!(txt.contains("qckm x1"));
}

#[test]
fn prop1_small_sweep_decays() {
    let cfg = Prop1Config {
        ms: vec![16, 64, 256],
        repeats: 12,
        reference_draws: 20_000,
        seed: 3,
    };
    let res = run_prop1(std::sync::Arc::new(crate::signature::UniversalQuantizer), &cfg);
    assert_eq!(res.mean_dev.len(), 3);
    assert!(res.gamma2 > 0.0);
    assert!(res.c_p > 0.0, "quantizer has harmonic tail, c_P > 0");
    // Deviation must shrink with m (allow noise: compare endpoints).
    assert!(
        res.mean_dev[2] < res.mean_dev[0],
        "no concentration: {:?}",
        res.mean_dev
    );
    // Decay exponent in a generous band around −0.5.
    assert!(
        (-1.0..=-0.15).contains(&res.decay_exponent),
        "decay exponent {}",
        res.decay_exponent
    );
    assert!(res.render().contains("gamma^2"));
}

#[test]
fn prop1_cosine_has_zero_cp() {
    let cfg = Prop1Config {
        ms: vec![32, 128],
        repeats: 8,
        reference_draws: 10_000,
        seed: 4,
    };
    let res = run_prop1(std::sync::Arc::new(crate::signature::Cosine), &cfg);
    assert!(res.c_p.abs() < 1e-12, "cosine c_P = {}", res.c_p);
}

#[test]
fn ablation_tiny_runs() {
    let cfg = AblationConfig {
        n: 4,
        k: 2,
        n_samples: 600,
        ratios: vec![4.0],
        trials: 2,
        seed: 9,
        ..Default::default()
    };
    let res = run_ablation(&cfg);
    // ckm, qckm bits 1..=4, triangle, modulo — all through the registry.
    assert_eq!(res.labels.len(), 7);
    assert!(res.success.iter().flatten().all(|v| (0.0..=1.0).contains(v)));
    // Bit accounting: qckm slot = 1 bit, ckm slot = 64 bits, same m; the
    // B-bit staircases interpolate at exactly B bits per slot.
    let q = res.labels.iter().position(|l| l.starts_with("qckm (1-bit")).unwrap();
    let c = res.labels.iter().position(|l| l.starts_with("ckm")).unwrap();
    let b3 = res.labels.iter().position(|l| l.contains("3-bit")).unwrap();
    assert!((res.bits_per_example[c][0] / res.bits_per_example[q][0] - 64.0).abs() < 1e-9);
    assert!((res.bits_per_example[b3][0] / res.bits_per_example[q][0] - 3.0).abs() < 1e-9);
    assert!(res.labels.iter().any(|l| l.starts_with("modulo")));
    assert!(res.render().contains("bits/ex"));
    let _ = SigmaHeuristic::default();
}

#[test]
fn transition_ratio_helper() {
    use super::common::transition_ratio;
    let ratios = [1.0, 2.0, 4.0];
    assert_eq!(transition_ratio(&ratios, &[0.0, 0.6, 1.0]), Some(2.0));
    assert_eq!(transition_ratio(&ratios, &[0.9, 1.0, 1.0]), Some(1.0));
    assert_eq!(transition_ratio(&ratios, &[0.0, 0.0, 0.4]), None);
}
