//! Decode-side benchmarks: CL-OMPR end-to-end at the paper's shapes, its
//! component solvers (NNLS, projected L-BFGS, Step-1 screening), and the
//! decoder-registry comparison — `clompr` vs `clompr:restarts=R` vs
//! `hier` wall-time and SSE across k ∈ {4, 16, 64}, emitted to
//! `BENCH_decode.json`.
//!
//! The paper's pitch is that decode cost is independent of N — verified
//! here by decoding sketches pooled from different dataset sizes.

#[path = "harness.rs"]
mod harness;

use harness::{bench, black_box, Summary};
use qckm::clompr::{ClOmpr, ClOmprParams};
use qckm::decoder::DecoderSpec;
use qckm::frequency::{DrawnFrequencies, FrequencyLaw};
use qckm::linalg::Mat;
use qckm::optim::nnls;
use qckm::rng::Rng;
use qckm::sketch::SketchOperator;
use std::path::PathBuf;

fn decode_case(n: usize, k: usize, m: usize, seed: u64) -> (SketchOperator, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let freqs = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, n, m, 1.4, &mut rng);
    let op = SketchOperator::quantized(freqs);
    let truth = Mat::from_fn(k, n, |_, _| if rng.next_f64() < 0.5 { -1.0 } else { 1.0 });
    let w = vec![1.0 / k as f64; k];
    // Sketch of the Dirac mixture through the full signature.
    let mut z = vec![0.0; op.sketch_len()];
    for (c, &alpha) in w.iter().enumerate() {
        let e = op.encode_point(truth.row(c));
        qckm::linalg::axpy(alpha, &e, &mut z);
    }
    (op, z)
}

fn main() {
    println!("== decoder benchmarks ==");

    // Fig. 2a-scale decode (n=8, K=2, m/nK = 2).
    let (op_small, z_small) = decode_case(8, 2, 32, 1);
    bench("clompr decode n=8 K=2 m=32", 1, 1500, || {
        let mut rng = Rng::new(7);
        black_box(
            ClOmpr::new(&op_small, 2)
                .with_bounds(vec![-2.0; 8], vec![2.0; 8])
                .run(&z_small, &mut rng),
        );
    })
    .print();

    // Fig. 3-scale decode (n=10, K=10, m=1000) — the flagship.
    let (op_big, z_big) = decode_case(10, 10, 1000, 2);
    bench("clompr decode n=10 K=10 m=1000 (fig3)", 0, 4000, || {
        let mut rng = Rng::new(8);
        black_box(
            ClOmpr::new(&op_big, 10)
                .with_bounds(vec![-2.0; 10], vec![2.0; 10])
                .run(&z_big, &mut rng),
        );
    })
    .print();

    // Component: NNLS at decoder shapes (2000 × 20).
    let mut rng = Rng::new(3);
    let a = Mat::from_fn(2000, 20, |_, _| rng.gaussian());
    let b: Vec<f64> = (0..2000).map(|_| rng.gaussian()).collect();
    bench("nnls 2000x20", 3, 300, || {
        black_box(nnls(&a, &b));
    })
    .print();

    // Component: Step-1 screening (64 candidates × atom eval).
    let v: Vec<f64> = (0..op_big.sketch_len()).map(|_| rng.gaussian()).collect();
    bench("step1 screen (64 atoms m=1000)", 3, 300, || {
        let mut r = Rng::new(4);
        let mut best = f64::NEG_INFINITY;
        for _ in 0..64 {
            let c: Vec<f64> = (0..10).map(|_| r.uniform(-2.0, 2.0)).collect();
            let s = qckm::linalg::dot(&op_big.atom(&c), &v);
            if s > best {
                best = s;
            }
        }
        black_box(best);
    })
    .print();

    // Decode cost is N-independent: same shapes, sketches from different N.
    println!("\n-- decode cost vs dataset size (must be flat) --");
    for &n_data in &[1_000usize, 10_000, 100_000] {
        let mut rng = Rng::new(5);
        let x = Mat::from_fn(n_data, 8, |_, _| rng.gaussian());
        let z = op_small.sketch_dataset(&x); // encode cost excluded
        bench(&format!("decode (sketch from N={n_data})"), 1, 800, || {
            let mut r = Rng::new(9);
            black_box(
                ClOmpr::new(&op_small, 2)
                    .with_bounds(vec![-3.0; 8], vec![3.0; 8])
                    .run(&z, &mut r),
            );
        })
        .print();
    }

    // ------------------------------------------------ decoder registry
    // clompr vs clompr:restarts=6 vs hier across k — hier's bisection is
    // O(K) cheap subproblems + one global polish, so its wall-time gap
    // over CL-OMPR's O(K²)-refinement outer loop widens with k; SSE shows
    // what that speed costs in quality. Base params are trimmed so the
    // k = 64 cells stay minutes, not hours — the comparison is relative.
    println!("\n== decoder registry: clompr vs clompr:restarts=6 vs hier ==");
    let base = ClOmprParams {
        step1_candidates: 32,
        step1_iters: 30,
        step5_iters: 30,
        step5_final_iters: 60,
        ..ClOmprParams::default()
    };
    let mut records: Vec<(String, Summary, f64)> = Vec::new();
    for &k in &[4usize, 16, 64] {
        let n = 8;
        let m = n * k; // fixed budget ratio m/(nK) = 1
        let mut rng = Rng::new(100 + k as u64);
        let data = qckm::data::gaussian_mixture_pm1(4096, n, k, &mut rng);
        let sigma = qckm::frequency::SigmaHeuristic::default().resolve(&data.points, &mut rng);
        let freqs = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, n, m, sigma, &mut rng);
        let op = SketchOperator::quantized(freqs);
        let z = op.sketch_dataset(&data.points);
        let (lo, hi) = qckm::linalg::bounding_box(&data.points);
        for spec_str in ["clompr", "clompr:restarts=6", "hier"] {
            let spec = DecoderSpec::parse(spec_str).expect("registry spec");
            let budget_ms = if k <= 16 { 800 } else { 1 };
            // Keep the last timed solution for the SSE column — every
            // iteration decodes from the same seed, so re-running outside
            // the timer would only repeat the identical (slow) decode.
            let mut sol = None;
            let summary = bench(
                &format!("{spec_str} decode n={n} K={k} m={m}"),
                usize::from(k <= 16),
                budget_ms,
                || {
                    sol = Some(black_box(spec.decode_best_of(
                        &op,
                        k,
                        &z,
                        lo.clone(),
                        hi.clone(),
                        &base,
                        1,
                        &mut Rng::new(9),
                    )));
                },
            );
            summary.print();
            let sol = sol.expect("bench ran at least once");
            let sse_per_n =
                qckm::metrics::sse(&data.points, &sol.centroids) / data.points.rows() as f64;
            println!("    SSE/N = {sse_per_n:.5}");
            records.push((format!("{spec_str}_k{k}"), summary, sse_per_n));
        }
    }
    write_decode_json(&records);

    let _ = ClOmprParams::default();
}

/// Emit the decoder-comparison records as `BENCH_decode.json` at the repo
/// root — machine-readable so successive PRs can track each decoder's
/// wall-time/quality trajectory (same convention as `BENCH_stream.json`).
fn write_decode_json(records: &[(String, Summary, f64)]) {
    let mut json = String::from(
        "{\n  \"bench\": \"decode\",\n  \"unit\": \"ns/iter\",\n  \"results\": [\n",
    );
    for (i, (name, s, sse_per_n)) in records.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{name}\", \"median_ns\": {:.0}, \"mean_ns\": {:.0}, \
             \"sse_per_n\": {sse_per_n:.6}}}{}\n",
            s.median_ns,
            s.mean_ns,
            if i + 1 == records.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("BENCH_decode.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("(decode bench results written to {})", path.display()),
        Err(e) => eprintln!("(could not write {}: {e})", path.display()),
    }
}
