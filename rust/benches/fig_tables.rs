//! "Bench" target that regenerates miniature versions of every paper
//! figure in one run — the per-figure timing makes grid-cost planning
//! concrete, and CI gets an end-to-end smoke of the experiment harnesses.
//!
//! The real (recorded) grids run via `qckm experiment <fig> [--full]`; this
//! target keeps each under a few seconds.

#[path = "harness.rs"]
mod harness;

use qckm::experiments::*;
use std::time::Instant;

fn main() {
    println!("== paper-table regeneration (miniature grids) ==");

    // Fig. 2a (reduced).
    let t = Instant::now();
    let mut cfg = Fig2Config::quick(Fig2Variant::VaryDimension);
    cfg.values = vec![4, 8];
    cfg.ratios = vec![1.0, 2.0, 4.0];
    cfg.trials = 4;
    cfg.n_samples = 2048;
    let res = run_fig2(&cfg);
    println!("{}", res.render());
    println!("[fig2a mini: {:.1}s]\n", t.elapsed().as_secs_f64());

    // Fig. 2b (reduced).
    let t = Instant::now();
    let mut cfg = Fig2Config::quick(Fig2Variant::VaryClusters);
    cfg.values = vec![2, 4];
    cfg.ratios = vec![2.0, 4.0, 8.0];
    cfg.trials = 4;
    cfg.n_samples = 2048;
    let res = run_fig2(&cfg);
    println!("{}", res.render());
    println!("[fig2b mini: {:.1}s]\n", t.elapsed().as_secs_f64());

    // Fig. 3 (reduced).
    let t = Instant::now();
    let mut cfg = Fig3Config::quick();
    cfg.n_samples = 4000;
    cfg.m = 300;
    cfg.trials = 3;
    let res = run_fig3(&cfg);
    println!("{}", res.render());
    println!("[fig3 mini: {:.1}s]\n", t.elapsed().as_secs_f64());

    // Prop. 1 (reduced).
    let t = Instant::now();
    let cfg = Prop1Config {
        ms: vec![64, 256, 1024],
        repeats: 16,
        reference_draws: 40_000,
        seed: 1,
    };
    let res = run_prop1(std::sync::Arc::new(qckm::signature::UniversalQuantizer), &cfg);
    println!("{}", res.render());
    println!("[prop1 mini: {:.1}s]\n", t.elapsed().as_secs_f64());

    // Ablation (reduced).
    let t = Instant::now();
    let cfg = AblationConfig {
        trials: 3,
        ratios: vec![2.0, 4.0],
        ..Default::default()
    };
    let res = run_ablation(&cfg);
    println!("{}", res.render());
    println!("[ablation mini: {:.1}s]", t.elapsed().as_secs_f64());

}
