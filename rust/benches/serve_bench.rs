//! Serving-path benchmarks: in-process `SketchService` ingest throughput
//! (the per-connection encode + accumulator merge), window-merge cost as
//! epochs accumulate, and query latency cold (CL-OMPR decode) vs cached
//! (fingerprint lookup) — the cache is the reason repeated dashboards
//! against an unchanged sketch are effectively free.
//!
//! Run: `cargo bench --offline`. Results land in `BENCH_serve.json`.

#[path = "harness.rs"]
mod harness;

use harness::{bench, black_box, Summary};
use qckm::method::MethodSpec;
use qckm::frequency::FrequencyLaw;
use qckm::linalg::Mat;
use qckm::parallel::Parallelism;
use qckm::rng::Rng;
use qckm::server::{QuerySpec, ServiceConfig, SketchService};
use qckm::stream::{draw_operator, SketchMeta};
use std::path::PathBuf;

const DIM: usize = 10;
const M: usize = 512;

fn service(threads: usize) -> SketchService {
    let qckm = MethodSpec::parse("qckm").unwrap();
    let op = draw_operator(&qckm, FrequencyLaw::AdaptedRadius, M, DIM, 1.0, 0);
    let meta = SketchMeta::for_operator(&op, &qckm, 0);
    SketchService::new(
        op,
        meta,
        ServiceConfig {
            threads: Parallelism::fixed(threads),
            ..ServiceConfig::default()
        },
    )
}

fn main() {
    println!("== sketch service benchmarks ==");
    let mut rng = Rng::new(1);
    let mut records: Vec<(String, Summary, f64)> = Vec::new();

    // Ingest throughput: one shard, repeated batches (encode dominates;
    // the accumulator merge under the lock is two vector adds).
    for (batch_rows, threads) in [(256usize, 1usize), (256, 4), (4096, 1), (4096, 4)] {
        let svc = service(threads);
        let batch = Mat::from_fn(batch_rows, DIM, |_, _| rng.gaussian());
        let s = bench(
            &format!("ingest {batch_rows}x{DIM} (threads {threads})"),
            2,
            if batch_rows > 1000 { 40 } else { 300 },
            || {
                black_box(svc.ingest("bench", &batch).unwrap());
            },
        );
        s.print_rate("rows", batch_rows as f64);
        records.push((
            format!("ingest_{batch_rows}x{DIM}_t{threads}"),
            s,
            batch_rows as f64,
        ));
    }

    // Window merge: cost of pooling e epochs × s shards at query time
    // (pure vector adds in stable order — no re-encoding).
    println!();
    for (epochs, shards) in [(4usize, 4usize), (16, 8)] {
        let svc = service(1);
        let batch = Mat::from_fn(64, DIM, |_, _| rng.gaussian());
        for _ in 0..epochs {
            for sh in 0..shards {
                svc.ingest(&format!("shard-{sh}"), &batch).unwrap();
            }
            svc.roll_epoch();
        }
        let s = bench(
            &format!("merge_window over {epochs} epochs x {shards} shards"),
            2,
            200,
            || {
                black_box(svc.merge_window(1 + epochs as u32).pool.count());
            },
        );
        s.print();
        records.push((format!("merge_window_e{epochs}_s{shards}"), s, 1.0));
    }

    // Query latency: cold decode vs cached. Small replicate count; the
    // point is the cold/cached ratio, not decoder tuning.
    println!();
    let svc = service(1);
    let mut data_rng = Rng::new(2);
    let data = qckm::data::gaussian_mixture_pm1(4096, DIM, 4, &mut data_rng);
    svc.ingest("bench", &data.points).unwrap();
    let spec = QuerySpec {
        k: 4,
        window: 0,
        replicates: 1,
        seed: None,
        lo: -2.0,
        hi: 2.0,
        decoder: String::new(),
    };
    let cold = bench("query cold (decode K=4, M=512)", 0, 3, || {
        // Vary the seed so every decode misses the cache.
        let mut s = spec.clone();
        s.seed = Some(black_box(rand_seed()));
        black_box(svc.query(&s).unwrap());
    });
    cold.print();
    records.push(("query_cold".into(), cold.clone(), 1.0));
    svc.query(&spec).unwrap(); // warm the cache for the fixed spec
    let cached = bench("query cached (same window, same spec)", 2, 200, || {
        let report = svc.query(&spec).unwrap();
        assert!(report.cached);
        black_box(report.objective);
    });
    cached.print();
    println!(
        "    cache speedup: {:.0}x (cold {:.3}ms -> cached {:.3}ms)",
        cold.median_ns / cached.median_ns,
        cold.median_ns / 1e6,
        cached.median_ns / 1e6
    );
    records.push(("query_cached".into(), cached, 1.0));

    write_serve_json(&records);
}

/// A fresh seed per cold query (wall-clock based; benches need no
/// reproducibility, just distinct cache keys).
fn rand_seed() -> u64 {
    use std::time::{SystemTime, UNIX_EPOCH};
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap()
        .subsec_nanos() as u64
        | 1 << 32
}

/// Emit the serving-path records as `BENCH_serve.json` at the repo root
/// (same shape as BENCH_stream.json).
fn write_serve_json(records: &[(String, Summary, f64)]) {
    let mut json =
        String::from("{\n  \"bench\": \"serve\",\n  \"unit\": \"ns/iter\",\n  \"results\": [\n");
    for (i, (name, s, per_iter)) in records.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{name}\", \"median_ns\": {:.0}, \"mean_ns\": {:.0}, \
             \"items_per_iter\": {per_iter}}}{}\n",
            s.median_ns,
            s.mean_ns,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("BENCH_serve.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("(could not write {}: {e})", path.display()),
    }
}
