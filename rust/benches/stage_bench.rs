//! Per-stage timing benchmark driven by the observability layer: instead
//! of timing only whole operations, each cell of a threads × rows grid
//! runs the streaming encode and reads back the per-stage histograms the
//! code under test feeds (`qckm_parallel_chunk_seconds`,
//! `qckm_stream_window_seconds`), and the decode section splits CL-OMPR
//! wall time into its Step-1 / Step-5 histograms — so the emitted records
//! show *where* the time went, not just how much there was.
//!
//! Run: `cargo bench --offline`. Results land in `BENCH_stage.json`.
//! `-- --smoke` shrinks every grid to a seconds-long sanity pass (the CI
//! mode: proves the bench and the JSON emitter still work, numbers are not
//! publication-grade).

#[path = "harness.rs"]
mod harness;

use harness::{bench, black_box, Summary};
use qckm::clompr::ClOmprParams;
use qckm::coordinator::WireFormat;
use qckm::decoder::DecoderSpec;
use qckm::frequency::FrequencyLaw;
use qckm::linalg::Mat;
use qckm::method::MethodSpec;
use qckm::obs::Histogram;
use qckm::parallel::Parallelism;
use qckm::rng::Rng;
use qckm::sketch::PooledSketch;
use qckm::stream::{draw_operator, MatChunkedReader};
use std::path::PathBuf;

const DIM: usize = 8;
const M: usize = 256;

/// One per-stage record: how many observations a stage histogram gained
/// over a bench cell, and how many seconds they summed to.
struct StageDelta {
    cell: String,
    stage: &'static str,
    count: u64,
    seconds: f64,
}

/// Snapshot a histogram's (count, sum) so a cell can report its delta.
fn snap(h: &Histogram) -> (u64, f64) {
    (h.count(), h.sum())
}

fn delta(cell: &str, stage: &'static str, h: &Histogram, before: (u64, f64)) -> StageDelta {
    let (count, sum) = snap(h);
    StageDelta {
        cell: cell.to_string(),
        stage,
        count: count - before.0,
        seconds: sum - before.1,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!(
        "== per-stage timing benchmarks (threads x rows grid{}) ==",
        if smoke { ", smoke mode" } else { "" }
    );
    let spec = MethodSpec::parse("qckm").unwrap();
    let op = draw_operator(&spec, FrequencyLaw::AdaptedRadius, M, DIM, 1.0, 0);
    let m = qckm::obs::lib_metrics();
    println!("compute kernels: {}", qckm::kernel::describe());

    let mut results: Vec<(String, Summary, f64)> = Vec::new();
    let mut stages: Vec<StageDelta> = Vec::new();

    // --- Streaming encode grid: rows × threads. The whole-cell Summary is
    // the outer wall time; the histogram deltas attribute it to windows
    // and chunks.
    let mut rng = Rng::new(3);
    let sketch_rows: &[usize] = if smoke { &[2048] } else { &[2048, 8192] };
    let sketch_threads: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    for &rows in sketch_rows {
        let data = Mat::from_fn(rows, DIM, |_, _| rng.gaussian());
        for &threads in sketch_threads {
            let cell = format!("sketch_{rows}x{DIM}_t{threads}");
            let par = Parallelism::fixed(threads);
            let window_before = snap(&m.stream_window_seconds);
            let chunk_before = snap(&m.parallel_chunk_seconds);
            let budget = if smoke {
                20
            } else if rows > 4096 {
                60
            } else {
                150
            };
            let s = bench(&cell, 1, budget, || {
                let mut reader = MatChunkedReader::new(&data);
                let mut pool = PooledSketch::new(op.sketch_len());
                qckm::stream::sketch_reader(
                    &op,
                    &mut reader,
                    WireFormat::DenseF64,
                    &mut pool,
                    &par,
                )
                .unwrap();
                black_box(pool.count());
            });
            s.print_rate("rows", rows as f64);
            stages.push(delta(&cell, "stream_window", &m.stream_window_seconds, window_before));
            stages.push(delta(&cell, "parallel_chunk", &m.parallel_chunk_seconds, chunk_before));
            results.push((cell, s, rows as f64));
        }
    }

    // --- Encode-kernel comparison: the identical parallel encode under
    // each forced dispatch mode (I-22 guarantees identical *outputs*, so
    // any delta here is pure kernel speed). `qckm` exercises the bit-panel
    // + SIMD projection path, `ckm` (cosine) the SIMD dot/axpy side alone.
    println!();
    let ckm_op = draw_operator(
        &MethodSpec::parse("ckm").unwrap(),
        FrequencyLaw::AdaptedRadius,
        M,
        DIM,
        1.0,
        0,
    );
    let kernel_rows: usize = if smoke { 2048 } else { 8192 };
    let kernel_threads: &[usize] = if smoke { &[1] } else { &[1, 4] };
    let kernel_data = Mat::from_fn(kernel_rows, DIM, |_, _| rng.gaussian());
    for (op_name, kop) in [("qckm", &op), ("ckm", &ckm_op)] {
        for &threads in kernel_threads {
            let par = Parallelism::fixed(threads);
            for mode in [
                qckm::kernel::KernelMode::Scalar,
                qckm::kernel::KernelMode::Wide,
            ] {
                qckm::kernel::set_mode(mode);
                let cell = format!(
                    "encode_kernel_{op_name}_{}_{kernel_rows}x{DIM}_t{threads}",
                    mode.name()
                );
                let s = bench(&cell, 1, if smoke { 20 } else { 60 }, || {
                    black_box(op_sketch(kop, &kernel_data, &par));
                });
                s.print_rate("rows", kernel_rows as f64);
                results.push((cell, s, kernel_rows as f64));
            }
        }
    }
    qckm::kernel::set_mode(qckm::kernel::default_mode());

    // --- Decode split: one CL-OMPR decode per iteration; the Step-1 /
    // Step-5 histogram deltas split the decoder's wall time into its two
    // dominant phases (the gap to the whole-decode time is NNLS + glue).
    // Skipped in smoke mode (a single decode dwarfs the smoke budget).
    if smoke {
        write_stage_json(&results, &stages);
        return;
    }
    println!();
    let mut data_rng = Rng::new(7);
    let mix = qckm::data::gaussian_mixture_pm1(4096, DIM, 4, &mut data_rng);
    let z = op.sketch_dataset_par(&mix.points, &Parallelism::fixed(2));
    let decoder = DecoderSpec::parse("clompr").unwrap();
    for threads in [1usize, 4] {
        let cell = format!("decode_k4_m{M}_t{threads}");
        let params = ClOmprParams {
            threads,
            ..ClOmprParams::default()
        };
        let step1_before = snap(&m.clompr_step1_seconds);
        let step5_before = snap(&m.clompr_step5_seconds);
        let decode_before = snap(&qckm::obs::decode_seconds("clompr"));
        let mut seed = 0u64;
        let s = bench(&cell, 0, 2, || {
            seed += 1;
            let sol = decoder.decode_best_of(
                &op,
                4,
                &z,
                vec![-2.0; DIM],
                vec![2.0; DIM],
                &params,
                1,
                &mut Rng::new(seed),
            );
            black_box(sol.objective);
        });
        s.print();
        stages.push(delta(&cell, "clompr_step1", &m.clompr_step1_seconds, step1_before));
        stages.push(delta(&cell, "clompr_step5", &m.clompr_step5_seconds, step5_before));
        stages.push(delta(
            &cell,
            "decode_total",
            &qckm::obs::decode_seconds("clompr"),
            decode_before,
        ));
        results.push((cell, s, 1.0));
    }

    write_stage_json(&results, &stages);
}

/// One full parallel encode — the unit of work the kernel-comparison cells
/// time under each dispatch mode.
fn op_sketch(op: &qckm::sketch::SketchOperator, x: &Mat, par: &Parallelism) -> u64 {
    let mut pool = PooledSketch::new(op.sketch_len());
    op.sketch_into_par(x, &mut pool, par);
    pool.count()
}

/// Emit `BENCH_stage.json` at the repo root: the usual per-cell timing
/// records plus the per-stage histogram deltas keyed by cell.
fn write_stage_json(results: &[(String, Summary, f64)], stages: &[StageDelta]) {
    let mut json =
        String::from("{\n  \"bench\": \"stage\",\n  \"unit\": \"ns/iter\",\n  \"results\": [\n");
    for (i, (name, s, per_iter)) in results.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{name}\", \"median_ns\": {:.0}, \"mean_ns\": {:.0}, \
             \"items_per_iter\": {per_iter}}}{}\n",
            s.median_ns,
            s.mean_ns,
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n  \"stages\": [\n");
    for (i, d) in stages.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"cell\": \"{}\", \"stage\": \"{}\", \"count\": {}, \"seconds\": {:.6}}}{}\n",
            d.cell,
            d.stage,
            d.count,
            d.seconds,
            if i + 1 < stages.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("BENCH_stage.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("(could not write {}: {e})", path.display()),
    }
}
