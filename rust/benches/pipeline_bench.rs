//! Layer-3 coordinator benchmarks: streaming acquisition throughput vs
//! worker count, wire format, and queue capacity (backpressure behaviour).

#[path = "harness.rs"]
mod harness;

use harness::bench;
use qckm::coordinator::{run_pipeline, PipelineConfig, SampleSource, WireFormat};
use qckm::frequency::{DrawnFrequencies, FrequencyLaw};
use qckm::rng::Rng;
use qckm::sketch::SketchOperator;
use std::sync::Arc;

fn main() {
    println!("== coordinator pipeline benchmarks ==");
    let dim = 10;
    let m = 500;
    let total = 20_000;
    let mut rng = Rng::new(0);
    let freqs = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, dim, m, 1.0, &mut rng);
    let op = SketchOperator::quantized(freqs.clone());
    let op_dense = SketchOperator::new(freqs, std::sync::Arc::new(qckm::signature::Cosine));
    let source = SampleSource::Synthetic {
        total,
        dim,
        make: Arc::new(|r: &mut Rng, out: &mut [f64]| {
            for v in out.iter_mut() {
                *v = r.gaussian();
            }
        }),
    };

    // Scaling with worker count (1-bit wire).
    for workers in [1usize, 2, 4, 8] {
        let cfg = PipelineConfig {
            workers,
            batch_size: 128,
            queue_capacity: 16,
            wire: WireFormat::PackedBits,
        };
        let s = bench(&format!("bits wire, {workers} workers ({total} samples)"), 1, 2500, || {
            harness::black_box(run_pipeline(&op, &source, &cfg, 1));
        });
        s.print_rate("samples", total as f64);
    }

    // Dense (CKM) wire at the same shapes.
    let cfg = PipelineConfig {
        workers: 4,
        batch_size: 128,
        queue_capacity: 16,
        wire: WireFormat::DenseF64,
    };
    bench(&format!("dense wire, 4 workers ({total} samples)"), 1, 2500, || {
        harness::black_box(run_pipeline(&op_dense, &source, &cfg, 1));
    })
    .print_rate("samples", total as f64);

    // Backpressure: a tiny queue must still complete (and report stalls).
    let tight = PipelineConfig {
        workers: 8,
        batch_size: 32,
        queue_capacity: 1,
        wire: WireFormat::PackedBits,
    };
    let rep = run_pipeline(&op, &source, &tight, 2);
    println!(
        "\nbackpressure probe: queue=1, 8 workers → {} stalls, high-water {}, {:.0} samples/s",
        rep.blocked_sends,
        rep.queue_high_water,
        rep.throughput()
    );
    assert_eq!(rep.samples, total as u64);
}
