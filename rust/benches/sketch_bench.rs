//! Encode-side hot-path benchmarks: the pooled sketch at the paper's
//! flagship shapes, native vs PJRT (AOT JAX/Pallas) engines, dense vs
//! bit-packed contribution encoding, and the decoder's atom kernels.
//!
//! Run: `cargo bench --offline` (this is the §Perf L1/L3-encode evidence).

#[path = "harness.rs"]
mod harness;

use harness::{bench, black_box, Summary};
use qckm::coordinator::WireFormat;
use qckm::data::save_f64_bin;
use qckm::frequency::{DrawnFrequencies, FrequencyLaw};
use qckm::linalg::Mat;
use qckm::parallel::Parallelism;
use qckm::rng::Rng;
use qckm::runtime::{ArtifactManifest, NativeEngine, PjrtEngine, SketchEngine};
use qckm::sketch::SketchOperator;
use std::path::PathBuf;

fn main() {
    println!("== sketch encode benchmarks ==");
    let mut rng = Rng::new(0);

    // Flagship Fig. 3 shapes: n = 10, M = 1000, batches of 256.
    let (n, m, batch) = (10usize, 1000usize, 256usize);
    let freqs = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, n, m, 1.0, &mut rng);
    let op = SketchOperator::quantized(freqs.clone());
    let x = Mat::from_fn(batch, n, |_, _| rng.gaussian());

    // Native engine, quantized signature.
    let native = NativeEngine::new(op.clone());
    let s = bench("native qckm sketch (256x10 -> 2000)", 3, 400, || {
        black_box(native.sketch_dataset(&x).unwrap());
    });
    s.print_rate("samples", batch as f64);
    let flops = 2.0 * batch as f64 * n as f64 * m as f64;
    println!(
        "    projection core: {:.2} GFLOP/s effective",
        flops / (s.median_ns * 1e-9) / 1e9
    );

    // Multi-thread scaling on the pooled-sketch hot path. The determinism
    // contract (qckm::parallel) guarantees identical output at every thread
    // count, so this is pure wall-clock: the acceptance bar is >= 2x
    // throughput at 4 threads over 1.
    let big_rows = 32_768usize; // 8 fixed chunks of PAR_CHUNK_ROWS
    let big = Mat::from_fn(big_rows, n, |_, _| rng.gaussian());
    let serial = bench(
        &format!("sketch_dataset_par {big_rows}x{n}, 1 thread"),
        1,
        1200,
        || {
            black_box(op.sketch_dataset_par(&big, &Parallelism::serial()));
        },
    );
    serial.print_rate("samples", big_rows as f64);
    for threads in [2usize, 4, 8] {
        let s = bench(
            &format!("sketch_dataset_par {big_rows}x{n}, {threads} threads"),
            1,
            1200,
            || {
                black_box(op.sketch_dataset_par(&big, &Parallelism::fixed(threads)));
            },
        );
        s.print_rate("samples", big_rows as f64);
        println!(
            "    scaling: {:.2}x vs 1 thread",
            serial.median_ns / s.median_ns
        );
    }

    // Streamed (out-of-core) vs in-memory sketching: the streaming fold is
    // bit-for-bit the in-memory one, so this section measures pure I/O +
    // windowing overhead. Results also land in BENCH_stream.json to start
    // the streamed-path perf trajectory.
    println!("\n== streamed vs in-memory sketch ==");
    let mut stream_records: Vec<(String, Summary, f64)> = Vec::new();
    let data_path = std::env::temp_dir().join("qckm_sketch_bench_stream.bin");
    save_f64_bin(&data_path, &big).expect("write bench dataset");
    for threads in [1usize, 4] {
        let par = Parallelism::fixed(threads);
        let s_mem = bench(
            &format!("in-memory sketch {big_rows}x{n}, {threads} threads"),
            1,
            800,
            || {
                black_box(op.sketch_dataset_par(&big, &par));
            },
        );
        s_mem.print_rate("samples", big_rows as f64);
        let mem_median_ns = s_mem.median_ns;
        stream_records.push((format!("in_memory_t{threads}"), s_mem, big_rows as f64));
        let s_stream = bench(
            &format!("streamed sketch {big_rows}x{n}, {threads} threads"),
            1,
            800,
            || {
                let pool = qckm::stream::sketch_file(&op, &data_path, WireFormat::DenseF64, &par)
                    .expect("streamed sketch");
                black_box(pool.mean());
            },
        );
        s_stream.print_rate("samples", big_rows as f64);
        println!(
            "    streaming overhead: {:.2}x the in-memory wall clock",
            s_stream.median_ns / mem_median_ns
        );
        stream_records.push((format!("streamed_t{threads}"), s_stream, big_rows as f64));
    }
    // Packed-bit pooling (the sensor acquisition encoding) through the same
    // streamed path.
    let s_bits = bench(
        &format!("streamed sketch bits {big_rows}x{n}, 4 threads"),
        1,
        800,
        || {
            let pool = qckm::stream::sketch_file(
                &op,
                &data_path,
                WireFormat::PackedBits,
                &Parallelism::fixed(4),
            )
            .expect("streamed bit sketch");
            black_box(pool.mean());
        },
    );
    s_bits.print_rate("samples", big_rows as f64);
    stream_records.push(("streamed_bits_t4".to_string(), s_bits, big_rows as f64));
    let _ = std::fs::remove_file(&data_path);
    write_stream_json(&stream_records);

    // Cosine signature (CKM) for the sincos-cost comparison.
    let op_c = SketchOperator::new(freqs.clone(), std::sync::Arc::new(qckm::signature::Cosine));
    let native_c = NativeEngine::new(op_c);
    bench("native ckm sketch (256x10 -> 2000)", 3, 400, || {
        black_box(native_c.sketch_dataset(&x).unwrap());
    })
    .print_rate("samples", batch as f64);

    // Per-point encode paths (sensor-side cost).
    let point: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    bench("encode_point dense (1x10 -> 2000 f64)", 10, 200, || {
        black_box(op.encode_point(&point));
    })
    .print_rate("points", 1.0);
    bench("encode_point_bits (1x10 -> 2000 bits)", 10, 200, || {
        black_box(op.encode_point_bits(&point));
    })
    .print_rate("points", 1.0);

    // Decode-side atom kernels (the CL-OMPR inner loop).
    let v: Vec<f64> = (0..op.sketch_len()).map(|_| rng.gaussian()).collect();
    let mut grad = vec![0.0; n];
    bench("atom (1 centroid, M=1000)", 10, 200, || {
        black_box(op.atom(&point));
    })
    .print();
    bench("atom_and_jtv (fused objective+grad)", 10, 200, || {
        black_box(op.atom_and_jtv(&point, &v, &mut grad));
    })
    .print();

    // PJRT engine (if artifacts are built).
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match ArtifactManifest::load(&dir) {
        Ok(manifest) => {
            let engine = PjrtEngine::load(&manifest, "sketch_qckm", op.clone()).expect("load");
            let s = bench("pjrt qckm sketch (256x10 -> 2000)", 3, 400, || {
                black_box(engine.sketch_dataset(&x).unwrap());
            });
            s.print_rate("samples", batch as f64);
        }
        Err(_) => println!("(pjrt bench skipped: run `make artifacts` first)"),
    }
}

/// Emit the streamed-vs-in-memory records as `BENCH_stream.json` at the
/// repo root — machine-readable so successive PRs can track the streamed
/// path's perf trajectory.
fn write_stream_json(records: &[(String, Summary, f64)]) {
    let mut json = String::from(
        "{\n  \"bench\": \"stream_sketch\",\n  \"unit\": \"ns/iter\",\n  \"results\": [\n",
    );
    for (i, (name, s, per_iter)) in records.iter().enumerate() {
        let rate = per_iter / (s.median_ns * 1e-9);
        json.push_str(&format!(
            "    {{\"name\": \"{name}\", \"median_ns\": {:.0}, \"mean_ns\": {:.0}, \
             \"samples_per_s\": {rate:.0}}}{}\n",
            s.median_ns,
            s.mean_ns,
            if i + 1 == records.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("BENCH_stream.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("(stream bench results written to {})", path.display()),
        Err(e) => eprintln!("(could not write {}: {e})", path.display()),
    }
}
