//! A minimal benchmark harness (no `criterion` offline): warmup + timed
//! iterations, reporting median / mean / MAD and derived throughput.
//!
//! Used by every `[[bench]]` target (they set `harness = false`).

// Included via `#[path]` from each bench; not every bench uses every item.
#![allow(dead_code)]

use std::time::Instant;

/// One benchmark's timing summary (nanoseconds per iteration).
#[derive(Clone, Debug)]
pub struct Summary {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub mad_ns: f64,
}

impl Summary {
    pub fn print(&self) {
        println!(
            "{:<44} {:>12} {:>12} ±{:>10}   ({} iters)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.mad_ns),
            self.iters
        );
    }

    /// Print with a derived rate (e.g. samples/s given samples/iter).
    pub fn print_rate(&self, unit: &str, per_iter: f64) {
        let rate = per_iter / (self.median_ns * 1e-9);
        println!(
            "{:<44} {:>12} median   {:>14.0} {unit}/s",
            self.name,
            fmt_ns(self.median_ns),
            rate
        );
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3}µs", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

/// Run `f` repeatedly: `warmup` throwaway iterations, then enough timed
/// iterations to cover ~`budget_ms` (at least 5).
pub fn bench(name: &str, warmup: usize, budget_ms: u64, mut f: impl FnMut()) -> Summary {
    for _ in 0..warmup {
        f();
    }
    // Estimate the per-iter cost from one timed call.
    let t0 = Instant::now();
    f();
    let est = t0.elapsed().as_nanos().max(1) as u64;
    let iters = ((budget_ms * 1_000_000) / est).clamp(5, 10_000) as usize;

    let mut times: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_nanos() as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    Summary {
        name: name.to_string(),
        iters,
        median_ns: median,
        mean_ns: mean,
        mad_ns: mad,
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
