//! Determinism suite for the parallel execution layer.
//!
//! The contract (see `qckm::parallel`): every parallel path — the pooled
//! sketch encode, the streaming coordinator, CL-OMPR's Step 1, the
//! experiment grids — produces output that is **bit-for-bit identical** at
//! every thread/worker/batch configuration, because chunk boundaries are
//! fixed by the input alone and floating-point reductions happen in a fixed
//! order. These tests pin that contract at thread counts {1, 2, 7} and
//! batch sizes {1, 64}, plus a golden seeded 2-cluster CL-OMPR decode
//! (Fig. 2a setup) so future performance work cannot silently change the
//! decoder's output.

use qckm::clompr::{ClOmpr, ClOmprParams, Solution};
use qckm::coordinator::{run_pipeline, PipelineConfig, SampleSource, WireFormat};
use qckm::data::gaussian_mixture_pm1;
use qckm::experiments::{run_fig2, Fig2Config, Fig2Variant};
use qckm::frequency::{DrawnFrequencies, FrequencyLaw, SigmaHeuristic};
use qckm::linalg::{bounding_box, Mat};
use qckm::parallel::Parallelism;
use qckm::rng::Rng;
use qckm::signature::Cosine;
use qckm::sketch::{SketchOperator, PAR_CHUNK_ROWS};
use std::path::PathBuf;
use std::sync::Arc;

fn quantized_op(n: usize, m: usize, seed: u64) -> SketchOperator {
    let mut rng = Rng::new(seed);
    SketchOperator::quantized(DrawnFrequencies::draw(
        FrequencyLaw::AdaptedRadius,
        n,
        m,
        1.0,
        &mut rng,
    ))
}

fn cosine_op(n: usize, m: usize, seed: u64) -> SketchOperator {
    let mut rng = Rng::new(seed);
    SketchOperator::new(
        DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, n, m, 1.0, &mut rng),
        Arc::new(Cosine),
    )
}

// ------------------------------------------------------------- sketch encode

#[test]
fn sketch_par_is_bitwise_thread_invariant_across_chunks() {
    // More rows than one PAR_CHUNK so several chunks are really in flight.
    let op = cosine_op(6, 40, 1);
    let mut rng = Rng::new(2);
    let rows = 2 * PAR_CHUNK_ROWS + 777;
    let x = Mat::from_fn(rows, 6, |_, _| rng.gaussian());
    let serial = op.sketch_dataset_par(&x, &Parallelism::serial());
    for threads in [2usize, 3, 7] {
        let par = op.sketch_dataset_par(&x, &Parallelism::fixed(threads));
        assert_eq!(par, serial, "threads = {threads} deviated bitwise");
    }
}

#[test]
fn sketch_par_matches_plain_serial_encode_within_one_chunk() {
    // For <= one chunk the parallel path must equal sketch_dataset exactly
    // (same fold, one partial merged into an empty pool).
    let op = quantized_op(5, 64, 3);
    let mut rng = Rng::new(4);
    let x = Mat::from_fn(1000, 5, |_, _| rng.gaussian());
    let want = op.sketch_dataset(&x);
    for threads in [1usize, 2, 7] {
        assert_eq!(op.sketch_dataset_par(&x, &Parallelism::fixed(threads)), want);
    }
}

// --------------------------------------------------------------- coordinator

/// Run the pipeline over every (workers, batch) in the contract grid and
/// assert all pooled sketches are bitwise identical to the first.
fn assert_pipeline_invariant(op: &SketchOperator, source: &SampleSource, wire: WireFormat) {
    let mut reference: Option<Vec<f64>> = None;
    for workers in [1usize, 2, 7] {
        for batch_size in [1usize, 64] {
            let report = run_pipeline(
                op,
                source,
                &PipelineConfig {
                    workers,
                    batch_size,
                    queue_capacity: 4,
                    wire,
                },
                9,
            );
            if let Some(want) = &reference {
                assert_eq!(
                    &report.sketch, want,
                    "pipeline ({wire:?}, workers {workers}, batch {batch_size}) deviated"
                );
            } else {
                reference = Some(report.sketch);
            }
        }
    }
}

#[test]
fn pipeline_shared_source_invariant_to_workers_and_batch() {
    // Span several SHARD_BLOCKs so the round-robin block assignment and the
    // dense reorder buffer are genuinely exercised.
    let mut rng = Rng::new(5);
    let x = Arc::new(Mat::from_fn(3000, 5, |_, _| rng.gaussian()));
    let source = SampleSource::Shared(x);
    assert_pipeline_invariant(&quantized_op(5, 32, 6), &source, WireFormat::PackedBits);
    assert_pipeline_invariant(&cosine_op(5, 32, 6), &source, WireFormat::DenseF64);
}

#[test]
fn pipeline_synthetic_source_invariant_to_workers_and_batch() {
    let source = SampleSource::Synthetic {
        total: 2500,
        dim: 4,
        make: Arc::new(|rng: &mut Rng, out: &mut [f64]| {
            for v in out.iter_mut() {
                *v = rng.gaussian();
            }
        }),
    };
    assert_pipeline_invariant(&quantized_op(4, 24, 7), &source, WireFormat::PackedBits);
    assert_pipeline_invariant(&cosine_op(4, 24, 7), &source, WireFormat::DenseF64);
}

#[test]
fn packed_bits_and_dense_wire_agree_exactly_for_quantizer() {
    // For the ±1 universal quantizer the dense f64 contributions are exact
    // small integers, so integer bit-counting and f64 pooling must agree to
    // the last bit, at any configuration.
    let op = quantized_op(6, 48, 8);
    let mut rng = Rng::new(9);
    let x = Arc::new(Mat::from_fn(2111, 6, |_, _| rng.gaussian()));
    let source = SampleSource::Shared(x);
    let run = |wire, workers, batch_size| {
        run_pipeline(
            &op,
            &source,
            &PipelineConfig {
                workers,
                batch_size,
                queue_capacity: 4,
                wire,
            },
            11,
        )
        .sketch
    };
    let bits = run(WireFormat::PackedBits, 1, 64);
    for workers in [1usize, 2, 7] {
        for batch_size in [1usize, 64] {
            assert_eq!(
                run(WireFormat::DenseF64, workers, batch_size),
                bits,
                "dense(workers {workers}, batch {batch_size}) != packed bits"
            );
        }
    }
}

// ------------------------------------------------------------------- decoder

fn fig2a_instance() -> (SketchOperator, Vec<f64>, Vec<f64>, Vec<f64>, Mat) {
    // Fig. 2a setup: K = 2 Gaussians at ±(1,…,1), cov (n/20)·Id, n = 8.
    let mut rng = Rng::new(0x51DE);
    let data = gaussian_mixture_pm1(4096, 8, 2, &mut rng);
    let sigma = SigmaHeuristic::default().resolve(&data.points, &mut rng);
    // m/(nK) = 12 — far past the Fig. 2a transition, so recovery is safe.
    let freqs = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, 8, 192, sigma, &mut rng);
    let op = SketchOperator::quantized(freqs);
    let z = op.sketch_dataset(&data.points);
    let (lo, hi) = bounding_box(&data.points);
    (op, z, lo, hi, data.points)
}

fn decode_fig2a(
    op: &SketchOperator,
    z: &[f64],
    lo: &[f64],
    hi: &[f64],
    threads: usize,
) -> Solution {
    let params = ClOmprParams {
        threads,
        ..ClOmprParams::default()
    };
    let mut rng = Rng::new(7);
    ClOmpr::new(op, 2)
        .with_bounds(lo.to_vec(), hi.to_vec())
        .with_params(params)
        .run(z, &mut rng)
}

/// Step 5 parallelizes its per-atom terms only once the support is big
/// enough (kc >= 4); a K = 5 decode exercises that path, which the K = 2
/// golden instance cannot.
#[test]
fn clompr_step5_parallel_path_is_bitwise_thread_invariant() {
    let mut rng = Rng::new(0xBEEF);
    let data = gaussian_mixture_pm1(3000, 5, 5, &mut rng);
    let sigma = SigmaHeuristic::default().resolve(&data.points, &mut rng);
    let freqs = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, 5, 120, sigma, &mut rng);
    let op = SketchOperator::quantized(freqs);
    let z = op.sketch_dataset(&data.points);
    let (lo, hi) = bounding_box(&data.points);
    let decode = |threads: usize| {
        let params = ClOmprParams {
            threads,
            step5_final_iters: 120,
            ..ClOmprParams::default()
        };
        let mut rng = Rng::new(3);
        ClOmpr::new(&op, 5)
            .with_bounds(lo.clone(), hi.clone())
            .with_params(params)
            .run(&z, &mut rng)
    };
    let reference = decode(1);
    for threads in [2usize, 7] {
        let sol = decode(threads);
        assert_eq!(
            sol.centroids.as_slice(),
            reference.centroids.as_slice(),
            "step-5 centroids deviated at threads = {threads}"
        );
        assert_eq!(sol.objective.to_bits(), reference.objective.to_bits());
    }
}

#[test]
fn clompr_decode_is_bitwise_thread_invariant() {
    let (op, z, lo, hi, _x) = fig2a_instance();
    let reference = decode_fig2a(&op, &z, &lo, &hi, 1);
    for threads in [2usize, 7, 0] {
        let sol = decode_fig2a(&op, &z, &lo, &hi, threads);
        assert_eq!(
            sol.centroids.as_slice(),
            reference.centroids.as_slice(),
            "centroids deviated at threads = {threads}"
        );
        assert_eq!(sol.weights, reference.weights, "weights at threads = {threads}");
        assert_eq!(
            sol.objective.to_bits(),
            reference.objective.to_bits(),
            "objective at threads = {threads}"
        );
    }
}

/// Golden regression: the seeded Fig. 2a decode must (a) recover the ±1⃗
/// centroids within tolerance and beat the paper's success criterion, and
/// (b) match the pinned bit-exact objective/centroids once a golden file is
/// blessed. Bless with `QCKM_BLESS_GOLDEN=1 cargo test golden_fig2a` —
/// after that, any drift in decoder numerics fails this test.
#[test]
fn golden_fig2a_two_cluster_decode() {
    let (op, z, lo, hi, x) = fig2a_instance();
    let sol = decode_fig2a(&op, &z, &lo, &hi, 1);

    // --- Quantitative recovery (always enforced).
    assert_eq!(sol.centroids.rows(), 2);
    let mut order: Vec<usize> = vec![0, 1];
    order.sort_by(|&a, &b| {
        sol.centroids.row(a)[0]
            .partial_cmp(&sol.centroids.row(b)[0])
            .unwrap()
    });
    for (row, want) in [(order[0], -1.0), (order[1], 1.0)] {
        for (j, &v) in sol.centroids.row(row).iter().enumerate() {
            assert!(
                (v - want).abs() < 0.4,
                "centroid {row} coord {j}: {v} vs {want}"
            );
        }
    }
    for &w in &sol.weights {
        assert!((w - 0.5).abs() < 0.2, "weights {:?}", sol.weights);
    }
    let s = qckm::metrics::sse(&x, &sol.centroids);
    let km = qckm::kmeans::kmeans(
        &x,
        2,
        &qckm::kmeans::KMeansParams {
            replicates: 5,
            ..Default::default()
        },
        &mut Rng::new(13),
    );
    assert!(
        qckm::metrics::is_success(s, km.sse),
        "decode SSE {s} vs k-means {}",
        km.sse
    );

    // --- Exact reproducibility (always enforced): same seeds, same bits.
    let again = decode_fig2a(&op, &z, &lo, &hi, 1);
    assert_eq!(again.centroids.as_slice(), sol.centroids.as_slice());
    assert_eq!(again.objective.to_bits(), sol.objective.to_bits());

    // --- Pinned golden value (enforced when the golden file exists).
    let mut record: Vec<u64> = vec![sol.objective.to_bits()];
    record.extend(sol.centroids.as_slice().iter().map(|v| v.to_bits()));
    record.extend(sol.weights.iter().map(|v| v.to_bits()));

    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden/fig2a_decode.golden");
    if path.exists() {
        let text = std::fs::read_to_string(&path).expect("read golden file");
        let pinned: Vec<u64> = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
            .map(|l| u64::from_str_radix(l, 16).expect("golden entries are hex u64"))
            .collect();
        assert_eq!(
            record, pinned,
            "decoder output drifted from the pinned golden record {}",
            path.display()
        );
    } else if std::env::var("QCKM_BLESS_GOLDEN").is_ok() {
        std::fs::create_dir_all(path.parent().unwrap()).expect("create golden dir");
        let mut text = String::from(
            "# Bit-exact record of the seeded Fig. 2a CL-OMPR decode\n\
             # (objective, then centroids row-major, then weights; f64 bits in hex).\n\
             # Regenerate with QCKM_BLESS_GOLDEN=1 after an intentional numerics change.\n",
        );
        for v in &record {
            text.push_str(&format!("{v:016X}\n"));
        }
        std::fs::write(&path, text).expect("write golden file");
        eprintln!("blessed golden record at {}", path.display());
    } else if std::env::var("QCKM_REQUIRE_GOLDEN").is_ok() {
        // CI sets QCKM_REQUIRE_GOLDEN so an absent pin *fails* the build
        // instead of silently skipping the bit-exact regression check.
        panic!(
            "golden pin {} is absent; on a machine with a rust toolchain run exactly:\n\
             \n\
             \tQCKM_BLESS_GOLDEN=1 cargo test --test determinism golden_fig2a_two_cluster_decode\n\
             \tgit add rust/tests/golden/fig2a_decode.golden\n\
             \tgit commit -m \"Bless fig2a golden decode pin\"\n\
             \n\
             then re-run CI. The pin is a text file of hex f64 bits (objective, centroids \
             row-major, weights) — see this test's source for the format.",
            path.display()
        );
    } else {
        eprintln!(
            "note: no golden file at {}; run QCKM_BLESS_GOLDEN=1 cargo test golden_fig2a to pin",
            path.display()
        );
    }
}

// ---------------------------------------------------------- kernels (I-22)

/// Serializes the kernel-mode-flipping tests in this binary and restores the
/// environment-resolved default mode when dropped. (A mid-test flip from a
/// concurrent test would be output-invisible — that is exactly I-22 — but
/// serializing keeps each comparison honest about which mode it measured.)
struct KernelModeLock(#[allow(dead_code)] std::sync::MutexGuard<'static, ()>);

impl Drop for KernelModeLock {
    fn drop(&mut self) {
        qckm::kernel::set_mode(qckm::kernel::default_mode());
    }
}

fn lock_kernel_mode() -> KernelModeLock {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    KernelModeLock(LOCK.lock().unwrap_or_else(|p| p.into_inner()))
}

/// Sketch `x` through `op` at the given thread count under a forced kernel
/// mode; the caller compares results across modes bitwise.
fn sketch_with_mode(
    op: &SketchOperator,
    x: &Mat,
    threads: usize,
    mode: qckm::kernel::KernelMode,
) -> Vec<f64> {
    qckm::kernel::set_mode(mode);
    let mut pool = qckm::sketch::PooledSketch::new(op.sketch_len());
    op.sketch_into_par(x, &mut pool, &Parallelism::fixed(threads));
    let mut out: Vec<f64> = pool.sum().to_vec();
    out.push(pool.count() as f64);
    out
}

fn mixed_zero_data(rows: usize, dim: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    // Exact zeros mixed in: the coordinates the legacy fold used to skip.
    Mat::from_fn(rows, dim, |_, _| {
        if rng.next_u64() % 4 == 0 {
            0.0
        } else {
            rng.gaussian()
        }
    })
}

/// I-22: flipping `QCKM_KERNEL` (here via `set_mode`) never changes any
/// output bit — for the ±1 quantizer (bit-panel + SIMD path), at row counts
/// straddling the 64-row panel and 4096-row chunk boundaries, at several
/// thread counts.
#[test]
fn i22_kernel_dispatch_is_bitwise_invisible_for_quantizer() {
    use qckm::kernel::KernelMode;
    let _lock = lock_kernel_mode();
    let op = quantized_op(5, 33, 21);
    for rows in [1usize, 63, 64, 65, 777, PAR_CHUNK_ROWS + 130] {
        let x = mixed_zero_data(rows, 5, rows as u64);
        for threads in [1usize, 2, 7] {
            let scalar = sketch_with_mode(&op, &x, threads, KernelMode::Scalar);
            let wide = sketch_with_mode(&op, &x, threads, KernelMode::Wide);
            let same = scalar
                .iter()
                .zip(&wide)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "rows = {rows}, threads = {threads}");
        }
    }
}

/// I-22 for the other canonical 1-bit spec, `qckm:bits=1` (canonicalized to
/// the universal quantizer by the method registry), and for the cosine
/// signature, which takes only the SIMD `dot`/`axpy` side of the dispatch.
#[test]
fn i22_kernel_dispatch_is_bitwise_invisible_for_bits1_and_cosine() {
    use qckm::kernel::KernelMode;
    use qckm::method::MethodSpec;
    let _lock = lock_kernel_mode();
    let bits1 = qckm::stream::draw_operator(
        &MethodSpec::parse("qckm:bits=1").unwrap(),
        FrequencyLaw::AdaptedRadius,
        40,
        4,
        1.0,
        31,
    );
    let cosine = cosine_op(4, 40, 31);
    for op in [&bits1, &cosine] {
        for rows in [65usize, 130] {
            let x = mixed_zero_data(rows, 4, 1000 + rows as u64);
            let scalar = sketch_with_mode(op, &x, 2, KernelMode::Scalar);
            let wide = sketch_with_mode(op, &x, 2, KernelMode::Wide);
            let same = scalar
                .iter()
                .zip(&wide)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(
                same,
                "sig = {}, rows = {rows}",
                op.signature().name()
            );
        }
    }
}

/// I-22 through the streaming layer: the `PackedBits` fold (bit-aggregator
/// chunks merged into a pool) produces identical one-counts in both kernel
/// modes, and both agree with the dense wire.
#[test]
fn i22_packed_bits_streaming_is_kernel_mode_invariant() {
    use qckm::kernel::KernelMode;
    let _lock = lock_kernel_mode();
    let op = quantized_op(6, 24, 77);
    let x = mixed_zero_data(2111, 6, 78);
    let par = Parallelism::fixed(3);
    let run = |wire, mode| {
        qckm::kernel::set_mode(mode);
        let mut pool = qckm::sketch::PooledSketch::new(op.sketch_len());
        let rows = qckm::stream::sketch_reader(
            &op,
            &mut qckm::stream::MatChunkedReader::new(&x),
            wire,
            &mut pool,
            &par,
        )
        .unwrap();
        assert_eq!(rows, 2111);
        pool.sum().to_vec()
    };
    let reference = run(WireFormat::PackedBits, KernelMode::Scalar);
    for (wire, mode) in [
        (WireFormat::PackedBits, KernelMode::Wide),
        (WireFormat::DenseF64, KernelMode::Scalar),
        (WireFormat::DenseF64, KernelMode::Wide),
    ] {
        let got = run(wire, mode);
        let same = got
            .iter()
            .zip(&reference)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "wire = {wire:?}, mode = {mode:?}");
    }
}

// --------------------------------------------------------------- experiments

#[test]
fn fig2_streamed_variant_matches_in_memory_grid() {
    // One-chunk datasets: the streamed fold is bitwise the in-memory fold,
    // so every trial decodes identically and the grids must agree exactly.
    let mut cfg = Fig2Config::quick(Fig2Variant::VaryDimension);
    cfg.values = vec![4];
    cfg.ratios = vec![1.0, 4.0];
    cfg.trials = 2;
    cfg.n_samples = 512;
    cfg.threads = 1;
    let reference = run_fig2(&cfg);
    cfg.streamed = true;
    let streamed = run_fig2(&cfg);
    assert_eq!(streamed.success, reference.success);
    assert_eq!(streamed.transitions, reference.transitions);
}

#[test]
fn fig2_grid_is_thread_invariant() {
    let mut cfg = Fig2Config::quick(Fig2Variant::VaryDimension);
    cfg.values = vec![4];
    cfg.ratios = vec![1.0, 4.0];
    cfg.trials = 2;
    cfg.n_samples = 512;
    cfg.threads = 1;
    let reference = run_fig2(&cfg);
    for threads in [2usize, 7] {
        cfg.threads = threads;
        let res = run_fig2(&cfg);
        assert_eq!(res.success, reference.success, "threads = {threads}");
        assert_eq!(res.transitions, reference.transitions);
    }
}
