//! Structured-fuzz corpus driver for every untrusted-input decoder: the
//! protocol frame/request/response parsers, the `.qsk` loader, and the
//! method/decoder spec grammars. See `INVARIANTS.md` ("Fuzz targets") for
//! the catalog these targets lock.
//!
//! Each target runs ≥ 10k seed-pinned mutated inputs (default 12k;
//! `QCKM_FUZZ_CASES` overrides, `QCKM_FUZZ_SEED` re-pins) built by
//! `qckm::testkit::fuzz::Mutator` from a corpus of *valid* encodings, and
//! asserts the contract of a hardened decoder:
//!
//! * **error, never panic** — every mutant returns `Ok`/`Err`, no unwind;
//! * **no hang** — decoding is linear in the input, enforced by the CI
//!   step's timeout;
//! * **no allocation above the documented caps** — a custom global
//!   allocator records the largest single allocation requested anywhere in
//!   this test binary and each target asserts it stayed under
//!   `MAX_FRAME_BYTES` (the largest cap any decoder is allowed to trust)
//!   plus harness slack;
//! * **canonicalization idempotence** — when a mutant *is* accepted,
//!   re-encoding and re-decoding it is a fixed point (compared on encoded
//!   bytes, so NaN payloads introduced by bit flips cannot produce false
//!   mismatches).

use qckm::frequency::FrequencyLaw;
use qckm::linalg::Mat;
use qckm::method::MethodSpec;
use qckm::decoder::DecoderSpec;
use qckm::obs::trace::TraceContext;
use qckm::rng::Rng;
use qckm::server::proto::{
    self, CentroidReport, QuerySpec, Request, Response, Scope, StatsReport, MAX_FRAME_BYTES,
};
use qckm::sketch::PooledSketch;
use qckm::stream::{
    draw_operator, read_sketch_from, write_sketch_to, ShardRecord, SketchMeta, QSK_MAGIC,
    QSK_VERSION_V1,
};
use qckm::testkit::fuzz::Mutator;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

// ------------------------------------------------------- allocation ceiling

/// Largest single allocation any decoder may trigger: the frame cap (the
/// biggest length any parser is allowed to trust) plus slack for the test
/// harness itself.
const ALLOC_CAP: usize = MAX_FRAME_BYTES + (1 << 20);

/// Wraps the system allocator to record the largest single allocation
/// requested by this test binary — the std-only way to prove "a corrupt
/// length field never turns into an unbounded allocation".
struct PeakTracking;

static PEAK_ALLOC: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for PeakTracking {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        PEAK_ALLOC.fetch_max(layout.size(), Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        PEAK_ALLOC.fetch_max(layout.size(), Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        PEAK_ALLOC.fetch_max(new_size, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: PeakTracking = PeakTracking;

fn assert_allocations_capped(target: &str) {
    let peak = PEAK_ALLOC.load(Ordering::Relaxed);
    assert!(
        peak <= ALLOC_CAP,
        "{target}: a single allocation of {peak} bytes exceeded the {ALLOC_CAP}-byte cap"
    );
}

// ------------------------------------------------------------ configuration

fn fuzz_cases() -> usize {
    std::env::var("QCKM_FUZZ_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(12_000)
}

/// Per-target seed: the pinned base (`QCKM_FUZZ_SEED` overrides) mixed
/// with an FNV of the target name, so targets never share mutation
/// streams and a failure names everything needed to reproduce it.
fn fuzz_seed(target: &str) -> u64 {
    let base: u64 = std::env::var("QCKM_FUZZ_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    target.bytes().fold(base ^ 0xcbf2_9ce4_8422_2325, |h, b| {
        (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
    })
}

// ----------------------------------------------------------------- corpora

fn corpus_trace() -> TraceContext {
    TraceContext {
        trace_id: *b"0123456789abcdef",
        parent_span: *b"fedcba98",
    }
}

fn request_corpus() -> Vec<Vec<u8>> {
    let requests = [
        Request::Push {
            scope: Scope::default(),
            shard: "sensor-7".into(),
            method: "qckm:bits=2".into(),
            dim: 3,
            data: vec![1.5, -2.25, 0.0, 4.0, 5.0, -6.0],
            trace: None,
        },
        Request::Push {
            scope: Scope::new("acme", "s3cret-token"),
            shard: "s".into(),
            method: String::new(),
            dim: 1,
            data: vec![0.25],
            trace: Some(corpus_trace()),
        },
        Request::Query {
            scope: Scope::new("acme", ""),
            spec: QuerySpec {
                k: 4,
                window: 2,
                replicates: 3,
                seed: Some(99),
                lo: -1.5,
                hi: 1.5,
                decoder: "clompr:restarts=5".into(),
            },
            method: "modulo".into(),
            trace: Some(corpus_trace()),
        },
        Request::Snapshot {
            scope: Scope::default(),
            window: 7,
            method: "qckm".into(),
            trace: None,
        },
        Request::Roll {
            scope: Scope::default(),
        },
        Request::Stats {
            scope: Scope::new("beta", "tok"),
        },
        Request::Metrics,
        Request::Trace {
            scope: Scope::default(),
            id: None,
            limit: 0,
        },
        Request::Trace {
            scope: Scope::default(),
            id: Some(corpus_trace().trace_id),
            limit: 16,
        },
        Request::Delta {
            scope: Scope::new("acme", "s3cret-token"),
            agg_id: "edge-1".into(),
            instance: 7,
            seq: 3,
            sketch: vec![0xAB; 32],
            trace: None,
        },
        Request::Shutdown,
    ];
    requests.iter().map(proto::encode_request).collect()
}

fn response_corpus() -> Vec<Vec<u8>> {
    let responses = [
        Response::Error("bad things happened".into()),
        Response::PushAck {
            shard_rows: 10,
            total_rows: 30,
        },
        Response::Centroids(CentroidReport {
            centroids: vec![0.5, -0.5, 1.0, -1.0],
            k: 2,
            dim: 2,
            weights: vec![0.25, 0.75],
            objective: 0.125,
            rows: 1000,
            epochs: 3,
            cached: true,
        }),
        Response::Snapshot(vec![1, 2, 3, 4, 5, 6, 7, 8]),
        Response::RollAck {
            epoch: 4,
            rows_closed: 512,
        },
        Response::Stats(StatsReport {
            method: "qckm:bits=3".into(),
            epoch: 2,
            rows_total: 77,
            epochs_held: 2,
            max_shards: 1024,
            cache_hits: 5,
            cache_misses: 6,
            shards: vec![("a".into(), 40), ("b".into(), 37)],
            decoders: vec![("clompr".into(), 9), ("hier".into(), 2)],
            tenant: "acme".into(),
            tenants: vec![("acme".into(), 77, 2), ("beta".into(), 0, 0)],
        }),
        Response::Busy {
            retry_after_ms: 120,
            message: "per-connection ingest rate limit".into(),
        },
        Response::DeltaAck {
            merged: true,
            rows_total: 4096,
        },
        Response::Metrics(
            "# HELP qckm_requests_total Requests received, by verb.\n\
             # TYPE qckm_requests_total counter\n\
             qckm_requests_total{verb=\"push\"} 3\n"
                .into(),
        ),
        Response::Traces(
            "{\n  \"traces\": [\n    {\n      \"trace_id\": \
             \"30313233343536373839616263646566\",\n      \"spans\": []\n    }\n  ]\n}"
                .into(),
        ),
        Response::ShutdownAck,
    ];
    responses.iter().map(proto::encode_response).collect()
}

/// Valid `.qsk` byte streams: current-writer v2 (legacy method) and v3
/// (parameterized method) with and without provenance, plus a crafted v1
/// stream (no provenance, no checksum) — every header generation the
/// reader promises to load.
fn qsk_corpus() -> Vec<Vec<u8>> {
    let mut corpus = Vec::new();
    for (spec_str, seed) in [("qckm", 21u64), ("qckm:bits=3", 22)] {
        let spec = MethodSpec::parse(spec_str).unwrap();
        let op = draw_operator(&spec, FrequencyLaw::AdaptedRadius, 16, 4, 1.0, seed);
        let mut rng = Rng::new(seed ^ 0xABCD);
        let x = Mat::from_fn(200, 4, |_, _| rng.gaussian());
        let mut pool = PooledSketch::new(op.sketch_len());
        op.sketch_into(&x, &mut pool);
        let meta = SketchMeta::for_operator(&op, &spec, seed);

        let mut bare = Vec::new();
        write_sketch_to(&mut bare, &meta, &pool, &[]).unwrap();
        corpus.push(bare);
        let prov = vec![
            ShardRecord {
                label: "shard_a".into(),
                rows: 120,
            },
            ShardRecord {
                label: "e7/sensor-12".into(),
                rows: 80,
            },
        ];
        let mut with_prov = Vec::new();
        write_sketch_to(&mut with_prov, &meta, &pool, &prov).unwrap();
        corpus.push(with_prov);

        // Crafted v1: header fields + payload only.
        let mut v1 = Vec::new();
        v1.extend_from_slice(&QSK_MAGIC);
        v1.extend_from_slice(&QSK_VERSION_V1.to_le_bytes());
        for s in [&meta.method, &meta.law] {
            v1.extend_from_slice(&(s.len() as u32).to_le_bytes());
            v1.extend_from_slice(s.as_bytes());
        }
        v1.extend_from_slice(&meta.sigma.to_le_bytes());
        v1.extend_from_slice(&meta.seed.to_le_bytes());
        v1.extend_from_slice(&meta.m.to_le_bytes());
        v1.extend_from_slice(&meta.d.to_le_bytes());
        v1.extend_from_slice(&pool.count().to_le_bytes());
        v1.extend_from_slice(&meta.config_hash.to_le_bytes());
        for &v in pool.sum() {
            v1.extend_from_slice(&v.to_le_bytes());
        }
        corpus.push(v1);
    }
    corpus
}

// ----------------------------------------------------------------- targets

#[test]
fn fuzz_decode_request_never_panics() {
    let corpus = request_corpus();
    let mut m = Mutator::new(fuzz_seed("decode_request"));
    for _ in 0..fuzz_cases() {
        let input = m.mutate(&corpus);
        if let Ok(req) = proto::decode_request(&input) {
            // Accepted mutants must be canonicalization fixed points.
            let canon = proto::encode_request(&req);
            let again = proto::decode_request(&canon)
                .expect("re-decoding a canonical encoding must succeed");
            assert_eq!(proto::encode_request(&again), canon);
        }
    }
    assert_allocations_capped("decode_request");
}

#[test]
fn fuzz_decode_response_never_panics() {
    let corpus = response_corpus();
    let mut m = Mutator::new(fuzz_seed("decode_response"));
    for _ in 0..fuzz_cases() {
        let input = m.mutate(&corpus);
        if let Ok(resp) = proto::decode_response(&input) {
            let canon = proto::encode_response(&resp);
            let again = proto::decode_response(&canon)
                .expect("re-decoding a canonical encoding must succeed");
            assert_eq!(proto::encode_response(&again), canon);
        }
    }
    assert_allocations_capped("decode_response");
}

#[test]
fn fuzz_read_frame_never_panics_or_overallocates() {
    // Corpus: whole frames (length prefix + payload), so mutations hit the
    // prefix as often as the body.
    let corpus: Vec<Vec<u8>> = request_corpus()
        .iter()
        .chain(response_corpus().iter())
        .map(|payload| {
            let mut frame = Vec::new();
            proto::write_frame(&mut frame, payload).unwrap();
            frame
        })
        .collect();
    let mut m = Mutator::new(fuzz_seed("read_frame"));
    for _ in 0..fuzz_cases() {
        let input = m.mutate(&corpus);
        match proto::read_frame(&mut &input[..]) {
            Ok(Some(payload)) => {
                assert!(!payload.is_empty());
                assert!(payload.len() <= MAX_FRAME_BYTES);
            }
            Ok(None) | Err(_) => {}
        }
    }
    assert_allocations_capped("read_frame");
}

/// Trace-heavy frames get their own target so the v5 trailing trace
/// block, the trace-verb body, and the traces response see concentrated
/// mutation pressure (the mixed corpus above dilutes them). v4 siblings
/// of the carrier requests ride along: a mutant that lands on a valid v4
/// frame decodes trace-free and re-encodes canonically at the current
/// version, which is itself a fixed point from the first re-decode on.
#[test]
fn fuzz_trace_frames_never_panic() {
    let mut corpus: Vec<Vec<u8>> = Vec::new();
    let traced = [
        Request::Push {
            scope: Scope::default(),
            shard: "s".into(),
            method: String::new(),
            dim: 2,
            data: vec![0.5, -0.5],
            trace: Some(corpus_trace()),
        },
        Request::Query {
            scope: Scope::default(),
            spec: QuerySpec {
                k: 2,
                window: 0,
                replicates: 1,
                seed: None,
                lo: -1.0,
                hi: 1.0,
                decoder: String::new(),
            },
            method: "qckm".into(),
            trace: Some(corpus_trace()),
        },
        Request::Snapshot {
            scope: Scope::default(),
            window: 0,
            method: String::new(),
            trace: Some(corpus_trace()),
        },
        Request::Trace {
            scope: Scope::default(),
            id: None,
            limit: 1,
        },
        Request::Trace {
            scope: Scope::default(),
            id: Some(corpus_trace().trace_id),
            limit: proto::MAX_TRACE_LIMIT,
        },
    ];
    corpus.extend(traced.iter().map(proto::encode_request));
    for req in traced.iter() {
        let mut v4 = req.clone();
        match &mut v4 {
            Request::Push { trace, .. }
            | Request::Query { trace, .. }
            | Request::Snapshot { trace, .. } => *trace = None,
            _ => continue, // the trace verb has no v4 form
        }
        corpus.push(proto::encode_request_v(&v4, 4).unwrap());
    }
    corpus.push(proto::encode_response(&Response::Traces("{\n  \"traces\": []\n}".into())));

    let mut m = Mutator::new(fuzz_seed("trace_frames"));
    for _ in 0..fuzz_cases() {
        let input = m.mutate(&corpus);
        if let Ok(req) = proto::decode_request(&input) {
            let canon = proto::encode_request(&req);
            let again = proto::decode_request(&canon)
                .expect("re-decoding a canonical encoding must succeed");
            assert_eq!(proto::encode_request(&again), canon);
        }
        if let Ok(resp) = proto::decode_response(&input) {
            let canon = proto::encode_response(&resp);
            let again = proto::decode_response(&canon)
                .expect("re-decoding a canonical encoding must succeed");
            assert_eq!(proto::encode_response(&again), canon);
        }
    }
    assert_allocations_capped("trace_frames");
}

/// Tenant-scoped and aggregation frames get the same concentrated
/// treatment: the v6 scope block (tenant + token, including both at their
/// maximum lengths), the delta verb carrying a real `.qsk` payload, and
/// the busy / delta-ack responses. v5 and v4 siblings of the scope-free
/// carriers ride along so mutants that land on an older-version frame
/// exercise the downgrade paths — those decode scope-free and re-encode
/// canonically at the current version, a fixed point from the first
/// re-decode on.
#[test]
fn fuzz_tenant_frames_never_panic() {
    let mut corpus: Vec<Vec<u8>> = Vec::new();

    // A genuine delta payload: the same construction `qckm aggregate`
    // flushes upstream, so mutations sit just off a real sketch stream.
    let spec = MethodSpec::parse("qckm:bits=2").unwrap();
    let op = draw_operator(&spec, FrequencyLaw::AdaptedRadius, 12, 3, 1.0, 31);
    let mut rng = Rng::new(31 ^ 0xABCD);
    let x = Mat::from_fn(50, 3, |_, _| rng.gaussian());
    let mut pool = PooledSketch::new(op.sketch_len());
    op.sketch_into(&x, &mut pool);
    let meta = SketchMeta::for_operator(&op, &spec, 31);
    let mut qsk = Vec::new();
    write_sketch_to(&mut qsk, &meta, &pool, &[]).unwrap();

    let scoped = [
        Request::Push {
            scope: Scope::new("acme", "s3cret-token"),
            shard: "edge/sensor-3".into(),
            method: "qckm:bits=2".into(),
            dim: 3,
            data: vec![0.5, -0.5, 1.0],
            trace: Some(corpus_trace()),
        },
        Request::Push {
            scope: Scope::new(
                "t".repeat(proto::MAX_TENANT_BYTES),
                "k".repeat(proto::MAX_TOKEN_BYTES),
            ),
            shard: "s".into(),
            method: String::new(),
            dim: 1,
            data: vec![0.25],
            trace: None,
        },
        Request::Query {
            scope: Scope::new("beta", ""),
            spec: QuerySpec {
                k: 2,
                window: 0,
                replicates: 1,
                seed: None,
                lo: -1.0,
                hi: 1.0,
                decoder: String::new(),
            },
            method: String::new(),
            trace: None,
        },
        Request::Snapshot {
            scope: Scope::new("acme", "s3cret-token"),
            window: 1,
            method: String::new(),
            trace: None,
        },
        Request::Roll {
            scope: Scope::new("acme", "s3cret-token"),
        },
        Request::Stats {
            scope: Scope::new("beta", "tok"),
        },
        Request::Trace {
            scope: Scope::new("acme", ""),
            id: None,
            limit: 4,
        },
        Request::Delta {
            scope: Scope::new("acme", "s3cret-token"),
            agg_id: "edge-1".into(),
            instance: 0x1122_3344_5566_7788,
            seq: 42,
            sketch: qsk,
            trace: Some(corpus_trace()),
        },
        Request::Delta {
            scope: Scope::default(),
            agg_id: "e".into(),
            instance: 1,
            seq: 1,
            sketch: vec![0; 8],
            trace: None,
        },
    ];
    corpus.extend(scoped.iter().map(proto::encode_request));
    for req in scoped.iter() {
        // Older-version siblings must be scope-free (and the delta verb
        // has no pre-v6 form at all).
        let mut old = req.clone();
        match &mut old {
            Request::Push { scope, .. }
            | Request::Query { scope, .. }
            | Request::Snapshot { scope, .. }
            | Request::Roll { scope }
            | Request::Stats { scope }
            | Request::Trace { scope, .. } => *scope = Scope::default(),
            _ => continue,
        }
        corpus.push(proto::encode_request_v(&old, 5).unwrap());
        let v4_ok = !matches!(
            &old,
            Request::Push { trace: Some(_), .. }
                | Request::Query { trace: Some(_), .. }
                | Request::Snapshot { trace: Some(_), .. }
                | Request::Trace { .. }
        );
        if v4_ok {
            corpus.push(proto::encode_request_v(&old, 4).unwrap());
        }
    }
    corpus.push(proto::encode_response(&Response::Busy {
        retry_after_ms: 20,
        message: "per-connection ingest rate limit".into(),
    }));
    corpus.push(proto::encode_response(&Response::DeltaAck {
        merged: false,
        rows_total: 77,
    }));

    let mut m = Mutator::new(fuzz_seed("tenant_frames"));
    for _ in 0..fuzz_cases() {
        let input = m.mutate(&corpus);
        if let Ok(req) = proto::decode_request(&input) {
            let canon = proto::encode_request(&req);
            let again = proto::decode_request(&canon)
                .expect("re-decoding a canonical encoding must succeed");
            assert_eq!(proto::encode_request(&again), canon);
        }
        if let Ok(resp) = proto::decode_response(&input) {
            let canon = proto::encode_response(&resp);
            let again = proto::decode_response(&canon)
                .expect("re-decoding a canonical encoding must succeed");
            assert_eq!(proto::encode_response(&again), canon);
        }
    }
    assert_allocations_capped("tenant_frames");
}

#[test]
fn fuzz_qsk_loader_never_panics() {
    let corpus = qsk_corpus();
    let mut m = Mutator::new(fuzz_seed("qsk_loader"));
    for _ in 0..fuzz_cases() {
        let input = m.mutate(&corpus);
        if let Ok((meta, pool, prov)) = read_sketch_from(&mut &input[..], "fuzz") {
            // Accepted mutants re-serialize and re-load to a fixed point
            // (a crafted v1 stream re-serializes as v2/v3, so compare the
            // *second* generation against the first).
            let mut canon = Vec::new();
            write_sketch_to(&mut canon, &meta, &pool, &prov)
                .expect("an accepted sketch must re-serialize");
            let (meta2, pool2, prov2) = read_sketch_from(&mut &canon[..], "fuzz-canon")
                .expect("re-reading a canonical serialization must succeed");
            let mut canon2 = Vec::new();
            write_sketch_to(&mut canon2, &meta2, &pool2, &prov2).unwrap();
            assert_eq!(canon2, canon);
        }
    }
    assert_allocations_capped("qsk_loader");
}

#[test]
fn fuzz_spec_grammar_never_panics() {
    let valid = [
        "ckm",
        "qckm",
        "qckm:bits=3",
        "triangle",
        "modulo",
        "clompr",
        "clompr:restarts=5,replacements=2",
        "hier:restarts=4",
        "bisect",
    ];
    let corpus: Vec<Vec<u8>> = valid.iter().map(|s| s.as_bytes().to_vec()).collect();
    let mut m = Mutator::new(fuzz_seed("spec_grammar"));
    for case in 0..fuzz_cases() {
        // Alternate pure junk with byte-mutated valid specs: junk explores
        // the grammar broadly, mutants sit just off the happy path.
        let s = if case % 2 == 0 {
            m.junk_string(48)
        } else {
            String::from_utf8_lossy(&m.mutate(&corpus)).into_owned()
        };
        if let Ok(spec) = MethodSpec::parse(&s) {
            // Canonicalization is a fixed point of the grammar.
            let canon = spec.canonical().to_string();
            assert_eq!(MethodSpec::parse(&canon).unwrap().canonical(), canon);
        }
        if let Ok(spec) = DecoderSpec::parse(&s) {
            let canon = spec.canonical().to_string();
            assert_eq!(DecoderSpec::parse(&canon).unwrap().canonical(), canon);
        }
    }
    assert_allocations_capped("spec_grammar");
}
