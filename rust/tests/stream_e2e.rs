//! End-to-end tests of the stage-split CLI: `qckm sketch` on shards →
//! `qckm merge` → `qckm decode`, driven through the real binary
//! (`CARGO_BIN_EXE_qckm`), must reproduce the single-process pipeline's
//! centroids exactly — the `.qsk` distributed-acquisition contract.

use qckm::clompr::{decode_best_of, ClOmprParams};
use qckm::data::{gaussian_mixture_pm1, load_csv, save_csv};
use qckm::frequency::FrequencyLaw;
use qckm::linalg::Mat;
use qckm::method::MethodSpec;
use qckm::parallel::Parallelism;
use qckm::rng::Rng;
use qckm::sketch::PooledSketch;
use qckm::stream::{draw_operator, load_sketch};
use std::path::{Path, PathBuf};
use std::process::Command;

const M: usize = 48;
const DIM: usize = 5;
const K: usize = 2;
const SIGMA: f64 = 1.2;
const SEED: u64 = 7;

fn work_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qckm_stream_e2e_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run the qckm binary; panic with its stderr if it fails.
fn qckm_ok(args: &[&str]) {
    let out = Command::new(env!("CARGO_BIN_EXE_qckm"))
        .args(args)
        .output()
        .expect("spawn qckm");
    assert!(
        out.status.success(),
        "qckm {:?} failed:\n{}",
        args,
        String::from_utf8_lossy(&out.stderr)
    );
}

/// Run the qckm binary expecting failure; return its stderr.
fn qckm_err(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_qckm"))
        .args(args)
        .output()
        .expect("spawn qckm");
    assert!(
        !out.status.success(),
        "qckm {args:?} unexpectedly succeeded"
    );
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn sketch_args<'a>(data: &'a str, out: &'a str, threads: &'a str) -> Vec<&'a str> {
    vec![
        "sketch", "--data", data, "--out", out, "--method", "qckm", "--m", "48", "--sigma",
        "1.2", "--seed", "7", "--threads", threads,
    ]
}

fn write_fixture(dir: &Path) -> (Mat, String, String, String) {
    let mut rng = Rng::new(1);
    let data = gaussian_mixture_pm1(3000, DIM, K, &mut rng);
    let full = dir.join("full.csv");
    save_csv(&full, &data.points).unwrap();
    // An uneven split that is NOT a multiple of the encode batch or chunk
    // sizes — merge exactness must not depend on alignment.
    let rows_a: Vec<usize> = (0..1337).collect();
    let rows_b: Vec<usize> = (1337..3000).collect();
    let shard_a = dir.join("shard_a.csv");
    let shard_b = dir.join("shard_b.csv");
    save_csv(&shard_a, &data.points.select_rows(&rows_a)).unwrap();
    save_csv(&shard_b, &data.points.select_rows(&rows_b)).unwrap();
    (
        data.points,
        full.display().to_string(),
        shard_a.display().to_string(),
        shard_b.display().to_string(),
    )
}

#[test]
fn sharded_sketch_merge_decode_equals_single_process() {
    let dir = work_dir("stages");
    let (x, full, shard_a, shard_b) = write_fixture(&dir);
    let full_qsk = dir.join("full.qsk").display().to_string();
    let a_qsk = dir.join("a.qsk").display().to_string();
    let b_qsk = dir.join("b.qsk").display().to_string();
    let merged_qsk = dir.join("merged.qsk").display().to_string();

    // Stage 1: sketch the whole dataset and the two shards as separate
    // processes, at different thread counts (results must not care).
    qckm_ok(&sketch_args(&full, &full_qsk, "1"));
    qckm_ok(&sketch_args(&shard_a, &a_qsk, "2"));
    qckm_ok(&sketch_args(&shard_b, &b_qsk, "7"));

    // Stage 2: merge the shard sketches.
    qckm_ok(&["merge", "--out", &merged_qsk, &a_qsk, &b_qsk]);

    // The merged pool must be bit-for-bit the full-dataset pool (the 1-bit
    // quantizer pools exact integer sums), and both must equal the library
    // encode on the in-memory dataset.
    let (meta_full, pool_full) = load_sketch(Path::new(&full_qsk)).unwrap();
    let (meta_merged, pool_merged) = load_sketch(Path::new(&merged_qsk)).unwrap();
    assert_eq!(meta_full, meta_merged);
    assert_eq!(pool_full.count(), 3000);
    assert_eq!(pool_merged.count(), 3000);
    assert_eq!(pool_full.sum(), pool_merged.sum());
    let op = draw_operator(
        &MethodSpec::parse("qckm").unwrap(),
        FrequencyLaw::AdaptedRadius,
        M,
        DIM,
        SIGMA,
        SEED,
    );
    let z_lib = op.sketch_dataset_par(&x, &Parallelism::serial());
    assert_eq!(pool_full.mean(), z_lib);
    assert_eq!(pool_merged.mean(), z_lib);

    // Stage 3: decode both sketches; centroids must match exactly.
    let c_full = dir.join("c_full.csv").display().to_string();
    let c_merged = dir.join("c_merged.csv").display().to_string();
    let decode = |qsk: &str, out: &str| {
        qckm_ok(&[
            "decode", "--sketch", qsk, "--k", "2", "--lo", "-2", "--hi", "2", "--out", out,
        ]);
    };
    decode(&full_qsk, &c_full);
    decode(&merged_qsk, &c_merged);
    let cf = load_csv(Path::new(&c_full)).unwrap();
    let cm = load_csv(Path::new(&c_merged)).unwrap();
    assert_eq!(cf.shape(), (K, DIM));
    assert_eq!(
        cf.as_slice(),
        cm.as_slice(),
        "sharded and single-process centroids must be identical"
    );

    // And both must equal the in-process library decode on the same sketch
    // (`qckm decode` defaults its RNG to the sketch's seed).
    let sol = decode_best_of(
        &op,
        K,
        &z_lib,
        vec![-2.0; DIM],
        vec![2.0; DIM],
        &ClOmprParams::default(),
        1,
        &mut Rng::new(SEED),
    );
    assert_eq!(cf.as_slice(), sol.centroids.as_slice());
}

#[test]
fn merge_refuses_shards_from_different_draws() {
    let dir = work_dir("mismatch");
    let (_x, _full, shard_a, shard_b) = write_fixture(&dir);
    let a_qsk = dir.join("a.qsk").display().to_string();
    let b_qsk = dir.join("b.qsk").display().to_string();
    let merged = dir.join("merged.qsk").display().to_string();

    qckm_ok(&sketch_args(&shard_a, &a_qsk, "1"));
    // Same shape but a different seed → different frequency draw.
    qckm_ok(&[
        "sketch", "--data", &shard_b, "--out", &b_qsk, "--method", "qckm", "--m", "48",
        "--sigma", "1.2", "--seed", "8", "--threads", "1",
    ]);
    let err = qckm_err(&["merge", "--out", &merged, &a_qsk, &b_qsk]);
    assert!(
        err.contains("refusing to merge"),
        "unexpected merge error: {err}"
    );
    assert!(!Path::new(&merged).exists(), "merge must not write on failure");
}

/// The stage-split pipeline end-to-end for *parameterized / new* method
/// specs: `--method qckm:bits=2` (the multi-bit staircase, finally
/// reachable from the CLI) and `--method modulo` (the phase-shifted ramp).
/// Dense-pooled sums are floating-point folds, so the assertion compares
/// the CLI result against the library running the *same* shard-wise fold —
/// bitwise — rather than against a single-process whole-dataset sketch.
/// (Both shards fit one 4096-row chunk, so shard folds are unambiguous.)
#[test]
fn parameterized_methods_sketch_merge_decode_end_to_end() {
    for spec_str in ["qckm:bits=2", "modulo"] {
        let tag = format!("param_{}", spec_str.replace([':', '='], "_"));
        let dir = work_dir(&tag);
        let (x, _full, shard_a, shard_b) = write_fixture(&dir);
        let a_qsk = dir.join("a.qsk").display().to_string();
        let b_qsk = dir.join("b.qsk").display().to_string();
        let merged_qsk = dir.join("merged.qsk").display().to_string();
        let c_csv = dir.join("c.csv").display().to_string();

        let sketch = |data: &str, out: &str, threads: &str| {
            qckm_ok(&[
                "sketch", "--data", data, "--out", out, "--method", spec_str, "--m", "48",
                "--sigma", "1.2", "--seed", "7", "--threads", threads,
            ]);
        };
        sketch(&shard_a, &a_qsk, "2");
        sketch(&shard_b, &b_qsk, "3");
        // merge/decode accept the spec as a declaration and verify it
        // against the .qsk headers.
        qckm_ok(&["merge", "--method", spec_str, "--out", &merged_qsk, &a_qsk, &b_qsk]);
        qckm_ok(&[
            "decode", "--sketch", &merged_qsk, "--method", spec_str, "--k", "2", "--lo", "-2",
            "--hi", "2", "--out", &c_csv,
        ]);
        let err = qckm_err(&[
            "decode", "--sketch", &merged_qsk, "--method", "qckm", "--k", "2",
        ]);
        assert!(err.contains("conflicts with"), "unexpected error: {err}");

        // Library reference with the identical shard-wise fold.
        let spec = MethodSpec::parse(spec_str).unwrap();
        let op = draw_operator(&spec, FrequencyLaw::AdaptedRadius, M, DIM, SIGMA, SEED);
        let xa = x.select_rows(&(0..1337).collect::<Vec<_>>());
        let xb = x.select_rows(&(1337..3000).collect::<Vec<_>>());
        let mut pool = PooledSketch::new(op.sketch_len());
        op.sketch_into_par(&xa, &mut pool, &Parallelism::serial());
        op.sketch_into_par(&xb, &mut pool, &Parallelism::serial());

        let (meta, pool_cli) = load_sketch(Path::new(&merged_qsk)).unwrap();
        assert_eq!(meta.method, spec.canonical(), "{spec_str}");
        assert_eq!(pool_cli.count(), 3000);
        assert_eq!(pool_cli.sum(), pool.sum(), "{spec_str}: CLI pool deviated");
        assert!(meta.rebuild_operator().is_ok());

        let sol = decode_best_of(
            &op,
            K,
            &pool.mean(),
            vec![-2.0; DIM],
            vec![2.0; DIM],
            &ClOmprParams::default(),
            1,
            &mut Rng::new(SEED),
        );
        let c = load_csv(Path::new(&c_csv)).unwrap();
        assert_eq!(
            c.as_slice(),
            sol.centroids.as_slice(),
            "{spec_str}: CLI centroids deviated from the library decode"
        );
    }
}

/// Junk method specs die at the CLI boundary with the registry's
/// actionable error (naming the valid families / accepted params).
#[test]
fn junk_method_specs_fail_actionably_at_the_cli() {
    let dir = work_dir("junk_method");
    let (_x, full, _a, _b) = write_fixture(&dir);
    let out = dir.join("x.qsk").display().to_string();
    let err = qckm_err(&[
        "sketch", "--data", &full, "--out", &out, "--method", "fourier", "--sigma", "1.2",
    ]);
    assert!(err.contains("valid families"), "unexpected error: {err}");
    let err = qckm_err(&[
        "sketch", "--data", &full, "--out", &out, "--method", "qckm:bits=99", "--sigma", "1.2",
    ]);
    assert!(err.contains("bits must be in 1..=16"), "unexpected error: {err}");
}

#[test]
fn decode_refuses_corrupt_and_foreign_files() {
    let dir = work_dir("corrupt");
    let garbage = dir.join("garbage.qsk");
    std::fs::write(&garbage, b"not a sketch at all").unwrap();
    let err = qckm_err(&[
        "decode", "--sketch", &garbage.display().to_string(), "--k", "2",
    ]);
    assert!(err.contains("bad magic"), "unexpected decode error: {err}");
}
