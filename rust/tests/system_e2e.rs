//! Whole-system integration tests (no PJRT required): coordinator →
//! pooled sketch → decoder → metrics, on realistic workloads; CLI-level
//! config plumbing; failure injection.

use qckm::clompr::{decode_best_of, ClOmpr, ClOmprParams};
use qckm::config::JobConfig;
use qckm::coordinator::{run_pipeline, PipelineConfig, SampleSource, WireFormat};
use qckm::data::gaussian_mixture_pm1;
use qckm::frequency::{DrawnFrequencies, FrequencyLaw, SigmaHeuristic};
use qckm::kmeans::{kmeans, KMeansParams};
use qckm::linalg::bounding_box;
use qckm::metrics::{adjusted_rand_index, assign_labels, is_success, sse};
use qckm::rng::Rng;
use qckm::sketch::SketchOperator;
use std::sync::Arc;

/// The full Fig.-1 loop: distributed 1-bit acquisition through the
/// coordinator, decode on the leader, quality vs k-means.
#[test]
fn sensor_cloud_to_centroids() {
    let (n, k, n_samples) = (6, 3, 20_000);
    let mut rng = Rng::new(11);
    let data = gaussian_mixture_pm1(n_samples, n, k, &mut rng);
    let sigma = SigmaHeuristic::default().resolve(&data.points, &mut rng);
    let freqs = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, n, 150, sigma, &mut rng);
    let op = SketchOperator::quantized(freqs);

    let report = run_pipeline(
        &op,
        &SampleSource::Shared(Arc::new(data.points.clone())),
        &PipelineConfig {
            workers: 6,
            batch_size: 256,
            queue_capacity: 8,
            wire: WireFormat::PackedBits,
        },
        3,
    );
    assert_eq!(report.samples, n_samples as u64);
    // Wire: ⌈300/64⌉ = 5 words = 40 bytes per example.
    assert_eq!(report.payload_bytes, n_samples as u64 * 40);

    let (lo, hi) = bounding_box(&data.points);
    let sol = ClOmpr::new(&op, k)
        .with_bounds(lo, hi)
        .run(&report.sketch, &mut rng);
    let km = kmeans(
        &data.points,
        k,
        &KMeansParams {
            replicates: 5,
            ..Default::default()
        },
        &mut rng,
    );
    let s = sse(&data.points, &sol.centroids);
    assert!(
        is_success(s, km.sse),
        "QCKM SSE {s} vs kmeans {} on an easy mixture",
        km.sse
    );
    let ari = adjusted_rand_index(&assign_labels(&data.points, &sol.centroids), &data.labels);
    assert!(ari > 0.8, "ARI {ari}");
}

/// The sketch is linear: two disjoint sensor fleets can be pooled and must
/// decode identically to one fleet seeing everything.
#[test]
fn federated_sketch_merge_decodes_identically() {
    let (n, k) = (4, 2);
    let mut rng = Rng::new(21);
    let data = gaussian_mixture_pm1(8_000, n, k, &mut rng);
    let sigma = SigmaHeuristic::default().resolve(&data.points, &mut rng);
    let freqs = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, n, 80, sigma, &mut rng);
    let op = SketchOperator::quantized(freqs);

    // Fleet A gets rows [0, 3000), fleet B the rest.
    let xa = data.points.select_rows(&(0..3000).collect::<Vec<_>>());
    let xb = data.points.select_rows(&(3000..8000).collect::<Vec<_>>());
    let mut agg_a = qckm::sketch::BitAggregator::new(op.sketch_len());
    let mut agg_b = qckm::sketch::BitAggregator::new(op.sketch_len());
    for i in 0..xa.rows() {
        agg_a.add(&op.encode_point_bits(xa.row(i)));
    }
    for i in 0..xb.rows() {
        agg_b.add(&op.encode_point_bits(xb.row(i)));
    }
    agg_a.merge(&agg_b);
    let merged = agg_a.mean();
    let direct = op.sketch_dataset(&data.points);
    for (a, b) in merged.iter().zip(&direct) {
        assert!((a - b).abs() < 1e-12, "merge must be exact (integer counts)");
    }
}

/// Replicate selection by the sketch objective (the paper's data-free
/// model selection) must never pick a worse-objective solution.
#[test]
fn objective_based_replicate_selection() {
    let (n, k) = (5, 3);
    let mut rng = Rng::new(31);
    let data = gaussian_mixture_pm1(6_000, n, k, &mut rng);
    let sigma = SigmaHeuristic::default().resolve(&data.points, &mut rng);
    let freqs = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, n, 120, sigma, &mut rng);
    let op = SketchOperator::quantized(freqs);
    let z = op.sketch_dataset(&data.points);
    let (lo, hi) = bounding_box(&data.points);

    let mut singles = Vec::new();
    let mut rng_a = Rng::new(5);
    for _ in 0..4 {
        singles.push(
            ClOmpr::new(&op, k)
                .with_bounds(lo.clone(), hi.clone())
                .run(&z, &mut rng_a),
        );
    }
    let mut rng_b = Rng::new(5);
    let best = decode_best_of(
        &op,
        k,
        &z,
        lo,
        hi,
        &ClOmprParams::default(),
        4,
        &mut rng_b,
    );
    let min_single = singles
        .iter()
        .map(|s| s.objective)
        .fold(f64::INFINITY, f64::min);
    assert!(
        (best.objective - min_single).abs() < 1e-9,
        "best-of must equal the min over the same replicate stream"
    );
}

/// Config file → JobConfig → operator plumbing.
#[test]
fn job_config_round_trip_drives_pipeline() {
    let cfg = JobConfig::from_toml_str(
        "seed = 9\n[sketch]\nnum_frequencies = 64\nmethod = \"qckm\"\nsigma = 1.5\n\
         [decode]\nk = 2\n[pipeline]\nworkers = 3\nwire = \"bits\"\n",
    )
    .unwrap();
    assert_eq!(cfg.sketch.method.canonical(), "qckm");
    let mut rng = Rng::new(cfg.seed);
    let data = gaussian_mixture_pm1(2_000, 3, cfg.decode.k, &mut rng);
    let sigma = cfg.sketch.sigma.resolve(&data.points, &mut rng);
    assert_eq!(sigma, 1.5);
    let freqs = DrawnFrequencies::draw(cfg.sketch.law, 3, cfg.sketch.num_frequencies, sigma, &mut rng);
    let op = SketchOperator::new(freqs, cfg.sketch.method.signature());
    let report = run_pipeline(
        &op,
        &SampleSource::Shared(Arc::new(data.points.clone())),
        &cfg.pipeline,
        cfg.seed,
    );
    assert_eq!(report.samples, 2000);
    assert_eq!(report.sketch.len(), 128);
}

/// Failure injection: a worker that panics must not hang the pipeline
/// (scoped threads propagate the panic instead of deadlocking).
#[test]
fn panicking_sensor_fails_loudly_not_silently() {
    let mut rng = Rng::new(41);
    let freqs = DrawnFrequencies::draw(FrequencyLaw::Gaussian, 2, 8, 1.0, &mut rng);
    let op = SketchOperator::quantized(freqs);
    let source = SampleSource::Synthetic {
        total: 1000,
        dim: 2,
        make: Arc::new(|r: &mut Rng, out: &mut [f64]| {
            if r.next_f64() < 0.01 {
                panic!("sensor hardware fault injection");
            }
            out.fill(0.5);
        }),
    };
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_pipeline(&op, &source, &PipelineConfig::default(), 1)
    }));
    assert!(result.is_err(), "injected fault must propagate");
}

/// Degenerate inputs: constant dataset, K = 1.
#[test]
fn degenerate_single_cluster() {
    let mut rng = Rng::new(51);
    let x = qckm::linalg::Mat::from_fn(500, 3, |_, c| c as f64); // all rows equal
    let freqs = DrawnFrequencies::draw(FrequencyLaw::Gaussian, 3, 40, 1.0, &mut rng);
    let op = SketchOperator::quantized(freqs);
    let z = op.sketch_dataset(&x);
    let sol = ClOmpr::new(&op, 1)
        .with_bounds(vec![-1.0, 0.0, 1.0], vec![1.0, 2.0, 3.0])
        .run(&z, &mut rng);
    // The single centroid should land on (0, 1, 2).
    for (j, &v) in sol.centroids.row(0).iter().enumerate() {
        assert!((v - j as f64).abs() < 0.15, "coord {j}: {v}");
    }
    assert!(sse(&x, &sol.centroids) < 500.0 * 0.1);
}
