//! End-to-end integration: AOT artifacts (JAX/Pallas → HLO text) loaded and
//! executed through the PJRT CPU client from Rust, validated against the
//! native engine.
//!
//! Requires `make artifacts` to have produced `artifacts/` at the repo root
//! (the Makefile runs it before `cargo test`). Tests self-skip with a
//! message when the artifacts are absent so `cargo test` alone stays green.

use qckm::frequency::{DrawnFrequencies, FrequencyLaw};
use qckm::linalg::Mat;
use qckm::rng::Rng;
use qckm::runtime::{ArtifactManifest, NativeEngine, PjrtEngine, SketchEngine};
use qckm::signature::{Cosine, UniversalQuantizer};
use qckm::sketch::SketchOperator;
use std::path::PathBuf;
use std::sync::Arc;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

/// Build the operator matching an artifact's lowered shapes.
fn operator_for(manifest: &ArtifactManifest, name: &str, quantized: bool) -> SketchOperator {
    let entry = manifest.find(name).expect("artifact in manifest");
    let mut rng = Rng::new(0xA07);
    let freqs = DrawnFrequencies::draw(
        FrequencyLaw::AdaptedRadius,
        entry.dim,
        entry.m,
        1.0,
        &mut rng,
    );
    if quantized {
        SketchOperator::new(freqs, Arc::new(UniversalQuantizer))
    } else {
        SketchOperator::new(freqs, Arc::new(Cosine))
    }
}

#[test]
fn qckm_artifact_matches_native_engine() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = ArtifactManifest::load(&dir).expect("manifest loads");
    let op = operator_for(&manifest, "sketch_qckm", true);
    let engine = PjrtEngine::load(&manifest, "sketch_qckm", op.clone()).expect("PJRT load");
    assert_eq!(engine.name(), "pjrt");
    assert_eq!(engine.batch(), 256);
    assert!(!engine.platform().is_empty());

    // 2.5 batches: exercises both the PJRT path and the native remainder.
    let mut rng = Rng::new(1);
    let x = Mat::from_fn(640, op.dim(), |_, _| rng.gaussian_with(0.0, 1.5));
    let via_pjrt = engine.sketch_dataset(&x).expect("pjrt sketch");
    let via_native = NativeEngine::new(op).sketch_dataset(&x).unwrap();

    // The quantizer is ±1-valued: disagreement requires a projection within
    // f32 round-off of a quantization boundary. Count per-slot deviation.
    let n = 640.0;
    let mut worst = 0.0f64;
    for (a, b) in via_pjrt.iter().zip(&via_native) {
        worst = worst.max((a - b).abs() * n); // in units of single flips (×2)
    }
    assert!(
        worst <= 4.0,
        "more than 2 boundary flips on one slot: {worst}"
    );
}

#[test]
fn ckm_artifact_matches_native_engine_closely() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = ArtifactManifest::load(&dir).expect("manifest loads");
    let op = operator_for(&manifest, "sketch_ckm", false);
    let engine = PjrtEngine::load(&manifest, "sketch_ckm", op.clone()).expect("PJRT load");

    let mut rng = Rng::new(2);
    let x = Mat::from_fn(512, op.dim(), |_, _| rng.gaussian());
    let via_pjrt = engine.sketch_dataset(&x).expect("pjrt sketch");
    let via_native = NativeEngine::new(op).sketch_dataset(&x).unwrap();
    // Smooth signature: f32 vs f64 differences only.
    for (i, (a, b)) in via_pjrt.iter().zip(&via_native).enumerate() {
        assert!(
            (a - b).abs() < 5e-5,
            "slot {i}: pjrt {a} vs native {b}"
        );
    }
}

#[test]
fn pjrt_pool_accumulates_across_calls() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = ArtifactManifest::load(&dir).expect("manifest loads");
    let op = operator_for(&manifest, "sketch_qckm", true);
    let engine = PjrtEngine::load(&manifest, "sketch_qckm", op.clone()).expect("PJRT load");
    let mut rng = Rng::new(3);
    let x1 = Mat::from_fn(256, op.dim(), |_, _| rng.gaussian());
    let x2 = Mat::from_fn(256, op.dim(), |_, _| rng.gaussian());
    let mut pool = qckm::sketch::PooledSketch::new(op.sketch_len());
    engine.sketch_into(&x1, &mut pool).unwrap();
    engine.sketch_into(&x2, &mut pool).unwrap();
    assert_eq!(pool.count(), 512);
    // Mean of the merged pool = mean of the concatenation.
    let mut all = x1.clone();
    for r in 0..x2.rows() {
        all.push_row(x2.row(r));
    }
    let whole = engine.sketch_dataset(&all).unwrap();
    for (a, b) in pool.mean().iter().zip(&whole) {
        assert!((a - b).abs() < 1e-6);
    }
}

#[test]
fn decoder_works_on_pjrt_produced_sketch() {
    // The full three-layer loop: JAX/Pallas-lowered artifact produces the
    // sketch, the Rust decoder extracts centroids from it.
    let Some(dir) = artifacts_dir() else { return };
    let manifest = ArtifactManifest::load(&dir).expect("manifest loads");
    let op = operator_for(&manifest, "sketch_qckm", true);
    let n = op.dim();

    // 2 well-separated Gaussians in the flagship 10-dim space.
    let mut rng = Rng::new(4);
    let mut x = Mat::zeros(0, n);
    for i in 0..1024 {
        let sign = if i % 2 == 0 { 1.0 } else { -1.0 };
        let row: Vec<f64> = (0..n).map(|_| sign * 1.0 + 0.4 * rng.gaussian()).collect();
        x.push_row(&row);
    }
    // Rescale the operator's frequencies to the data scale.
    let sigma = qckm::frequency::SigmaHeuristic::default().resolve(&x, &mut rng);
    let freqs = DrawnFrequencies::draw(
        FrequencyLaw::AdaptedRadius,
        n,
        manifest.find("sketch_qckm").unwrap().m,
        sigma,
        &mut rng,
    );
    let op = SketchOperator::new(freqs, Arc::new(UniversalQuantizer));
    let engine = PjrtEngine::load(&manifest, "sketch_qckm", op.clone()).expect("PJRT load");

    let z = engine.sketch_dataset(&x).unwrap();
    let (lo, hi) = qckm::linalg::bounding_box(&x);
    let sol = qckm::clompr::ClOmpr::new(&op, 2)
        .with_bounds(lo, hi)
        .run(&z, &mut rng);
    // Centroids near ±1⃗ (order-free check via their first coordinates).
    let mut c0: Vec<f64> = (0..2).map(|k| sol.centroids.row(k)[0]).collect();
    c0.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert!(c0[0] < -0.5 && c0[1] > 0.5, "centroids {c0:?}");
    let s = qckm::metrics::sse(&x, &sol.centroids);
    let km = qckm::kmeans::kmeans(&x, 2, &Default::default(), &mut rng);
    assert!(
        qckm::metrics::is_success(s, km.sse),
        "PJRT-sketch decode SSE {s} vs kmeans {}",
        km.sse
    );
}
