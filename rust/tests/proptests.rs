//! Property-based tests (via `qckm::testkit`) over the system's core
//! invariants: sketch linearity/merging, bit-packing exactness, coordinator
//! routing/batching, decoder feasibility, NNLS KKT, metrics ranges.

use qckm::coordinator::{run_pipeline, PipelineConfig, SampleSource, WireFormat};
use qckm::frequency::{DrawnFrequencies, FrequencyLaw};
use qckm::linalg::Mat;
use qckm::metrics::adjusted_rand_index;
use qckm::obs::trace::TraceContext;
use qckm::optim::nnls;
use qckm::parallel::Parallelism;
use qckm::rng::Rng;
use qckm::server::proto::{self, CentroidReport, QuerySpec, Request, Response, Scope, StatsReport};
use qckm::server::{ServiceConfig, SketchService};
use qckm::sketch::{BitAggregator, PooledSketch, SketchOperator};
use qckm::stream::{pool_fingerprint, read_sketch_from, write_sketch_to, ShardRecord, SketchMeta};
use qckm::testkit::{property, Gen};
use std::sync::Arc;

fn random_operator(g: &mut Gen, quantized: bool) -> SketchOperator {
    let n = g.usize_in(1, 8);
    let m = g.usize_in(1, 60);
    let law = if g.bool() {
        FrequencyLaw::Gaussian
    } else {
        FrequencyLaw::AdaptedRadius
    };
    let sigma = g.f64_in(0.3, 3.0);
    let freqs = DrawnFrequencies::draw(law, n, m, sigma, g.rng());
    if quantized {
        SketchOperator::quantized(freqs)
    } else {
        SketchOperator::new(freqs, Arc::new(qckm::signature::Cosine))
    }
}

#[test]
fn prop_sketch_is_linear_under_any_split() {
    property("sketch linearity", 40, |g| {
        let quantized = g.bool();
        let op = random_operator(g, quantized);
        let rows = g.usize_in(2, 120);
        let x = Mat::from_fn(rows, op.dim(), |_, _| g.gaussian());
        let split = g.usize_in(1, rows - 1);
        let a = x.select_rows(&(0..split).collect::<Vec<_>>());
        let b = x.select_rows(&(split..rows).collect::<Vec<_>>());
        let mut pa = PooledSketch::new(op.sketch_len());
        let mut pb = PooledSketch::new(op.sketch_len());
        op.sketch_into(&a, &mut pa);
        op.sketch_into(&b, &mut pb);
        pa.merge(&pb);
        let whole = op.sketch_dataset(&x);
        for (u, v) in pa.mean().iter().zip(&whole) {
            assert!((u - v).abs() < 1e-9, "split at {split} of {rows}");
        }
    });
}

#[test]
fn prop_bit_packing_round_trips_and_pools_exactly() {
    property("bit packing exactness", 40, |g| {
        let op = random_operator(g, true);
        let rows = g.usize_in(1, 80);
        let x = Mat::from_fn(rows, op.dim(), |_, _| 2.0 * g.gaussian());
        let mut agg = BitAggregator::new(op.sketch_len());
        for i in 0..rows {
            let bits = op.encode_point_bits(x.row(i));
            assert_eq!(bits.to_dense(), op.encode_point(x.row(i)));
            agg.add(&bits);
        }
        let dense = op.sketch_dataset(&x);
        for (u, v) in agg.mean().iter().zip(&dense) {
            assert!((u - v).abs() < 1e-12);
        }
    });
}

/// I-22 at property scale: the wide-mode bit-panel pooling (both the dense
/// `sketch_into` fold and the `pool_bits_range` aggregator path) equals the
/// forced-scalar legacy fold bitwise, on random quantized operators, row
/// counts straddling the 64-row panel, and data salted with exact zeros
/// (the coordinates the legacy projection used to branch over).
#[test]
fn prop_bit_panel_pooling_matches_scalar_fold_bitwise() {
    use qckm::kernel::{self, KernelMode};
    property("bit panel == scalar fold (bitwise)", 30, |g| {
        let op = random_operator(g, true);
        let rows = g.usize_in(1, 200);
        let x = Mat::from_fn(rows, op.dim(), |_, _| {
            if g.bool() {
                0.0
            } else {
                g.gaussian()
            }
        });

        kernel::set_mode(KernelMode::Scalar);
        let mut want = PooledSketch::new(op.sketch_len());
        op.sketch_into(&x, &mut want);
        let mut want_agg = BitAggregator::new(op.sketch_len());
        op.pool_bits_range(&x, 0..rows, &mut want_agg);

        kernel::set_mode(KernelMode::Wide);
        let mut got = PooledSketch::new(op.sketch_len());
        op.sketch_into(&x, &mut got);
        let mut got_agg = BitAggregator::new(op.sketch_len());
        op.pool_bits_range(&x, 0..rows, &mut got_agg);
        kernel::set_mode(kernel::default_mode());

        assert_eq!(got.count(), want.count());
        for (u, v) in got.sum().iter().zip(want.sum()) {
            assert_eq!(u.to_bits(), v.to_bits(), "rows {rows}");
        }
        assert_eq!(got_agg.count(), want_agg.count());
        assert_eq!(got_agg.to_sum(), want_agg.to_sum(), "rows {rows}");
    });
}

#[test]
fn prop_pipeline_invariant_to_workers_batch_queue() {
    property("pipeline routing/batching invariance", 15, |g| {
        let op = random_operator(g, true);
        let rows = g.usize_in(1, 300);
        let x = Arc::new(Mat::from_fn(rows, op.dim(), |_, _| g.gaussian()));
        let reference = op.sketch_dataset(&x);
        let cfg = PipelineConfig {
            workers: g.usize_in(1, 9),
            batch_size: g.usize_in(1, 50),
            queue_capacity: g.usize_in(1, 8),
            wire: WireFormat::PackedBits,
        };
        let rep = run_pipeline(&op, &SampleSource::Shared(x.clone()), &cfg, g.seed);
        assert_eq!(rep.samples, rows as u64, "cfg {cfg:?}");
        assert_eq!(
            rep.per_worker.iter().sum::<u64>(),
            rows as u64,
            "sharding must cover exactly"
        );
        for (u, v) in rep.sketch.iter().zip(&reference) {
            assert!((u - v).abs() < 1e-12, "cfg {cfg:?}");
        }
    });
}

#[test]
fn prop_parallel_sketch_equals_serial_bit_for_bit() {
    property("parallel sketch == serial", 25, |g| {
        let quantized = g.bool();
        let op = random_operator(g, quantized);
        let rows = g.usize_in(1, 500);
        let x = Mat::from_fn(rows, op.dim(), |_, _| g.gaussian());
        let serial = op.sketch_dataset(&x);
        let threads = g.usize_in(1, 8);
        let par = Parallelism::fixed(threads);
        // Whole-dataset mean and the accumulating entry point, both exact.
        assert_eq!(op.sketch_dataset_par(&x, &par), serial, "threads {threads}");
        let mut pool = PooledSketch::new(op.sketch_len());
        op.sketch_into_par(&x, &mut pool, &par);
        assert_eq!(pool.count(), rows as u64);
        assert_eq!(pool.mean(), serial, "sketch_into_par (threads {threads})");
    });
}

#[test]
fn prop_jtv_from_atom_matches_fused_kernel_and_finite_differences() {
    property("jtv_from_atom gradients", 40, |g| {
        let quantized = g.bool();
        let op = random_operator(g, quantized);
        let c = g.vec_gaussian(op.dim());
        let v = g.vec_gaussian(op.sketch_len());
        // Trig-free JᵀV from a precomputed atom vs the fused sincos kernel.
        let mut g_fused = vec![0.0; op.dim()];
        let atom = op.atom_and_jtv(&c, &v, &mut g_fused);
        let mut g_from_atom = vec![0.0; op.dim()];
        op.jtv_from_atom(&atom, &v, &mut g_from_atom);
        for (a, b) in g_fused.iter().zip(&g_from_atom) {
            assert!(
                (a - b).abs() <= 1e-9 * (1.0 + a.abs()),
                "fused {a} vs from-atom {b}"
            );
        }
        // Both must be the true gradient of c ↦ ⟨a(c), v⟩ (central FD).
        let dir = g.vec_gaussian(op.dim());
        let h = 1e-6;
        let cp: Vec<f64> = c.iter().zip(&dir).map(|(a, d)| a + h * d).collect();
        let cm: Vec<f64> = c.iter().zip(&dir).map(|(a, d)| a - h * d).collect();
        let fd = (qckm::linalg::dot(&op.atom(&cp), &v) - qckm::linalg::dot(&op.atom(&cm), &v))
            / (2.0 * h);
        let an = qckm::linalg::dot(&g_from_atom, &dir);
        assert!(
            (fd - an).abs() < 1e-4 * (1.0 + fd.abs()),
            "directional derivative {an} vs fd {fd}"
        );
    });
}

#[test]
fn prop_atom_norm_constant_and_jacobian_consistent() {
    property("atom norm + jacobian", 30, |g| {
        let op = random_operator(g, true);
        let c = g.vec_gaussian(op.dim());
        let a = op.atom(&c);
        let want = op.atom_norm();
        let got = qckm::linalg::norm2(&a);
        assert!((got - want).abs() < 1e-9 * want.max(1.0));
        // Directional derivative check of the fused JᵀV kernel.
        let v = g.vec_gaussian(op.sketch_len());
        let mut grad = vec![0.0; op.dim()];
        let _ = op.atom_and_jtv(&c, &v, &mut grad);
        let dir = g.vec_gaussian(op.dim());
        let h = 1e-6;
        let cp: Vec<f64> = c.iter().zip(&dir).map(|(a, d)| a + h * d).collect();
        let cm: Vec<f64> = c.iter().zip(&dir).map(|(a, d)| a - h * d).collect();
        let fd = (qckm::linalg::dot(&op.atom(&cp), &v) - qckm::linalg::dot(&op.atom(&cm), &v))
            / (2.0 * h);
        let an = qckm::linalg::dot(&grad, &dir);
        assert!(
            (fd - an).abs() < 1e-4 * (1.0 + fd.abs()),
            "directional derivative {an} vs fd {fd}"
        );
    });
}

#[test]
fn prop_nnls_kkt_on_random_problems() {
    property("nnls kkt", 40, |g| {
        let m = g.usize_in(4, 60);
        let n = g.usize_in(1, 8.min(m));
        let a = Mat::from_fn(m, n, |_, _| g.gaussian());
        let b = g.vec_gaussian(m);
        let x = nnls(&a, &b);
        assert!(x.iter().all(|&v| v >= 0.0));
        let r = qckm::linalg::sub(&b, &qckm::linalg::matvec(&a, &x));
        let w = qckm::linalg::matvec_t(&a, &r);
        for j in 0..n {
            if x[j] > 1e-9 {
                assert!(w[j].abs() < 1e-5, "stationarity w[{j}]={}", w[j]);
            } else {
                assert!(w[j] < 1e-5, "dual feasibility w[{j}]={}", w[j]);
            }
        }
    });
}

#[test]
fn prop_ari_bounds_and_permutation_invariance() {
    property("ari invariances", 40, |g| {
        let n = g.usize_in(2, 400);
        let k = g.usize_in(1, 6);
        let a: Vec<usize> = (0..n).map(|_| g.usize_in(0, k - 1)).collect();
        let b: Vec<usize> = (0..n).map(|_| g.usize_in(0, k - 1)).collect();
        let ari = adjusted_rand_index(&a, &b);
        assert!((-1.0..=1.0).contains(&ari));
        assert!((adjusted_rand_index(&a, &a) - 1.0).abs() < 1e-12);
        // Permute b's labels: ARI unchanged.
        let perm: Vec<usize> = {
            let mut p: Vec<usize> = (0..k).collect();
            g.rng().shuffle(&mut p);
            p
        };
        let b2: Vec<usize> = b.iter().map(|&l| perm[l]).collect();
        assert!((adjusted_rand_index(&a, &b2) - ari).abs() < 1e-12);
    });
}

#[test]
fn prop_decoder_output_always_feasible() {
    property("decoder feasibility", 8, |g| {
        let op = random_operator(g, true);
        let k = g.usize_in(1, 3);
        let rows = g.usize_in(50, 400);
        let x = Mat::from_fn(rows, op.dim(), |_, _| g.gaussian());
        let z = op.sketch_dataset(&x);
        let (lo, hi) = qckm::linalg::bounding_box(&x);
        let mut rng = Rng::new(g.seed);
        let sol = qckm::clompr::ClOmpr::new(&op, k)
            .with_bounds(lo.clone(), hi.clone())
            .run(&z, &mut rng);
        assert_eq!(sol.centroids.rows(), k);
        assert_eq!(sol.weights.len(), k);
        assert!((sol.weights.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(sol.weights.iter().all(|&w| w >= 0.0));
        for c in 0..k {
            for (j, &v) in sol.centroids.row(c).iter().enumerate() {
                assert!(
                    v >= lo[j] - 1e-9 && v <= hi[j] + 1e-9,
                    "centroid escapes the box"
                );
            }
        }
        assert!(sol.objective.is_finite());
    });
}

/// Merging shard pools in ANY order must be exact for integer-valued
/// contributions (the ±1 quantizer): float addition of small integers
/// commutes, which is what lets the live server merge shard accumulators
/// in shard-key order — whatever order pushes arrived in — and still
/// reproduce the offline pipeline bit-for-bit.
#[test]
fn prop_pooled_merge_is_order_invariant_for_integer_sums() {
    property("pooled merge order invariance (quantized)", 30, |g| {
        let op = random_operator(g, true);
        let shards = g.usize_in(2, 6);
        let pools: Vec<PooledSketch> = (0..shards)
            .map(|_| {
                let rows = g.usize_in(1, 60);
                let x = Mat::from_fn(rows, op.dim(), |_, _| g.gaussian());
                let mut pool = PooledSketch::new(op.sketch_len());
                op.sketch_into(&x, &mut pool);
                pool
            })
            .collect();
        // Identity order vs a random permutation (Fisher–Yates).
        let mut order: Vec<usize> = (0..shards).collect();
        for i in (1..shards).rev() {
            order.swap(i, g.usize_in(0, i));
        }
        let mut forward = PooledSketch::new(op.sketch_len());
        for p in &pools {
            forward.merge(p);
        }
        let mut permuted = PooledSketch::new(op.sketch_len());
        for &i in &order {
            permuted.merge(&pools[i]);
        }
        assert_eq!(permuted.count(), forward.count());
        assert_eq!(
            permuted.sum(),
            forward.sum(),
            "quantized pools must merge exactly in any order ({order:?})"
        );
    });
}

/// BitAggregator merging is order- AND grouping-invariant (integer
/// one-counts), and its (sum, count) export always matches pooling the
/// same contributions densely.
#[test]
fn prop_bit_aggregator_merge_is_order_and_grouping_invariant() {
    property("bit aggregator merge invariance", 30, |g| {
        let op = random_operator(g, true);
        let parts = g.usize_in(2, 5);
        let aggs: Vec<BitAggregator> = (0..parts)
            .map(|_| {
                let rows = g.usize_in(1, 40);
                let mut agg = BitAggregator::new(op.sketch_len());
                let mut dense = PooledSketch::new(op.sketch_len());
                for _ in 0..rows {
                    let x = g.vec_gaussian(op.dim());
                    let bits = op.encode_point_bits(&x);
                    dense.add(&bits.to_dense());
                    agg.add(&bits);
                }
                // Exported (sum, count) == dense pooling, bit for bit.
                let (sum, count) = agg.to_sum();
                assert_eq!(sum, dense.sum());
                assert_eq!(count, dense.count());
                agg
            })
            .collect();
        // Forward fold vs reverse fold vs a two-level (pairwise) grouping.
        let fold = |order: &mut dyn Iterator<Item = &BitAggregator>| {
            let mut acc = BitAggregator::new(op.sketch_len());
            for a in order {
                acc.merge(a);
            }
            acc
        };
        let forward = fold(&mut aggs.iter());
        let reverse = fold(&mut aggs.iter().rev());
        let mut grouped = BitAggregator::new(op.sketch_len());
        for pair in aggs.chunks(2) {
            let sub = fold(&mut pair.iter());
            grouped.merge(&sub);
        }
        assert_eq!(forward.count(), reverse.count());
        assert_eq!(forward.mean(), reverse.mean());
        assert_eq!(forward.to_sum(), reverse.to_sum());
        assert_eq!(forward.to_sum(), grouped.to_sum());
    });
}

// ---------------------------------------------------------------- protocol

fn ascii_label(g: &mut Gen, lo: usize, hi: usize) -> String {
    let len = g.usize_in(lo, hi);
    (0..len)
        .map(|_| (b'a' + g.usize_in(0, 25) as u8) as char)
        .collect()
}

fn random_query_spec(g: &mut Gen) -> QuerySpec {
    QuerySpec {
        k: g.usize_in(1, 64) as u32,
        window: g.usize_in(0, 20) as u32,
        replicates: g.usize_in(1, 5) as u32,
        seed: g.bool().then(|| g.rng().next_u64()),
        lo: g.f64_in(-10.0, 0.0),
        hi: g.f64_in(0.0, 10.0),
        decoder: if g.bool() { String::new() } else { "clompr".into() },
    }
}

fn random_trace_context(g: &mut Gen) -> TraceContext {
    let mut trace_id = [0u8; 16];
    let mut parent_span = [0u8; 8];
    trace_id[..8].copy_from_slice(&g.rng().next_u64().to_be_bytes());
    trace_id[8..].copy_from_slice(&g.rng().next_u64().to_be_bytes());
    parent_span.copy_from_slice(&g.rng().next_u64().to_be_bytes());
    TraceContext { trace_id, parent_span }
}

fn random_trace(g: &mut Gen) -> Option<TraceContext> {
    g.bool().then(|| random_trace_context(g))
}

/// Empty half the time (the pre-v6 shape every old client sends), else a
/// tenant name / token pair up to the wire caps.
fn random_scope(g: &mut Gen) -> Scope {
    if g.bool() {
        return Scope::default();
    }
    let tenant = ascii_label(g, 0, proto::MAX_TENANT_BYTES);
    let token = if g.bool() {
        String::new()
    } else {
        ascii_label(g, 1, proto::MAX_TOKEN_BYTES)
    };
    Scope::new(tenant, token)
}

fn random_request(g: &mut Gen) -> Request {
    match g.usize_in(0, 8) {
        0 => {
            let dim = g.usize_in(1, 6);
            let rows = g.usize_in(1, 20);
            Request::Push {
                scope: random_scope(g),
                shard: ascii_label(g, 1, 24),
                method: if g.bool() { String::new() } else { "qckm:bits=2".into() },
                dim: dim as u32,
                data: g.vec_gaussian(rows * dim),
                trace: random_trace(g),
            }
        }
        1 => Request::Query {
            scope: random_scope(g),
            spec: random_query_spec(g),
            method: ascii_label(g, 0, 8),
            trace: random_trace(g),
        },
        2 => Request::Snapshot {
            scope: random_scope(g),
            window: g.usize_in(0, 9) as u32,
            method: ascii_label(g, 0, 8),
            trace: random_trace(g),
        },
        3 => Request::Roll {
            scope: random_scope(g),
        },
        4 => Request::Stats {
            scope: random_scope(g),
        },
        5 => Request::Metrics,
        6 => Request::Trace {
            scope: random_scope(g),
            id: g.bool().then(|| random_trace_context(g).trace_id),
            limit: g.usize_in(0, proto::MAX_TRACE_LIMIT as usize) as u32,
        },
        7 => {
            let len = g.usize_in(1, 256);
            Request::Delta {
                scope: random_scope(g),
                agg_id: ascii_label(g, 1, 24),
                instance: g.rng().next_u64(),
                seq: g.rng().next_u64(),
                sketch: (0..len).map(|_| g.rng().next_u64() as u8).collect(),
                trace: random_trace(g),
            }
        }
        _ => Request::Shutdown,
    }
}

fn random_response(g: &mut Gen) -> Response {
    match g.usize_in(0, 10) {
        0 => Response::Error(ascii_label(g, 1, 200)),
        1 => Response::PushAck {
            shard_rows: g.rng().next_u64(),
            total_rows: g.rng().next_u64(),
        },
        9 => Response::Busy {
            retry_after_ms: g.rng().next_u64(),
            message: ascii_label(g, 0, 120),
        },
        10 => Response::DeltaAck {
            merged: g.bool(),
            rows_total: g.rng().next_u64(),
        },
        2 => {
            let k = g.usize_in(1, 8);
            let dim = g.usize_in(1, 6);
            Response::Centroids(CentroidReport {
                centroids: g.vec_gaussian(k * dim),
                k: k as u32,
                dim: dim as u32,
                weights: g.vec_f64(k, 0.0, 1.0),
                objective: g.gaussian(),
                rows: g.rng().next_u64(),
                epochs: g.usize_in(1, 99) as u32,
                cached: g.bool(),
            })
        }
        3 => {
            let len = g.usize_in(0, 512);
            Response::Snapshot((0..len).map(|_| g.rng().next_u64() as u8).collect())
        }
        4 => Response::RollAck {
            epoch: g.rng().next_u64(),
            rows_closed: g.rng().next_u64(),
        },
        5 => {
            let shards = (0..g.usize_in(0, 5))
                .map(|_| (ascii_label(g, 1, 16), g.rng().next_u64()))
                .collect();
            let decoders = (0..g.usize_in(0, 3))
                .map(|_| (ascii_label(g, 1, 16), g.rng().next_u64()))
                .collect();
            let tenants = (0..g.usize_in(0, 4))
                .map(|_| (ascii_label(g, 1, 16), g.rng().next_u64(), g.rng().next_u64()))
                .collect();
            Response::Stats(StatsReport {
                method: ascii_label(g, 1, 16),
                epoch: g.rng().next_u64(),
                rows_total: g.rng().next_u64(),
                epochs_held: g.usize_in(0, 64) as u32,
                max_shards: g.rng().next_u64(),
                cache_hits: g.rng().next_u64(),
                cache_misses: g.rng().next_u64(),
                shards,
                decoders,
                tenant: ascii_label(g, 0, 16),
                tenants,
            })
        }
        6 => Response::Metrics(ascii_label(g, 0, 400)),
        7 => Response::Traces(ascii_label(g, 0, 400)),
        _ => Response::ShutdownAck,
    }
}

/// A request is representable at proto v4 exactly when it carries no
/// v5/v6 content — no trace context, no tenant scope, and not one of the
/// newer verbs: those round-trip through a v4 frame unchanged, while the
/// rest refuse to encode rather than silently dropping fields.
#[test]
fn prop_v4_frames_round_trip_iff_v4_representable() {
    property("v4 encoding iff v4-representable", 300, |g| {
        let req = random_request(g);
        let traced = matches!(req, Request::Trace { .. }) || req.trace_context().is_some();
        let scoped = req.scope().is_some_and(|s| !s.is_empty());
        let delta = matches!(req, Request::Delta { .. });
        match proto::encode_request_v(&req, 4) {
            Ok(payload) => {
                assert!(
                    !traced && !scoped && !delta,
                    "v5/v6 content must not encode at v4: {req:?}"
                );
                assert_eq!(payload[0], 4, "the frame must carry the requested version");
                let (version, back) = proto::decode_request_v(&payload).unwrap();
                assert_eq!(version, 4);
                assert_eq!(back, req);
            }
            Err(e) => {
                assert!(
                    traced || scoped || delta,
                    "a v4-representable request must encode at v4: {req:?}"
                );
                assert!(format!("{e:#}").contains("needs proto v"), "{e:#}");
            }
        }
    });
}

/// The v6 capabilities gate independently of the v5 ones: at v5, exactly
/// the requests with a non-empty tenant scope or the delta verb refuse to
/// encode — traced requests are fine there.
#[test]
fn prop_v5_frames_round_trip_iff_unscoped() {
    property("v5 encoding iff unscoped and not delta", 300, |g| {
        let req = random_request(g);
        let scoped = req.scope().is_some_and(|s| !s.is_empty());
        let delta = matches!(req, Request::Delta { .. });
        match proto::encode_request_v(&req, 5) {
            Ok(payload) => {
                assert!(
                    !scoped && !delta,
                    "v6 content must not encode at v5: {req:?}"
                );
                assert_eq!(payload[0], 5);
                let (version, back) = proto::decode_request_v(&payload).unwrap();
                assert_eq!(version, 5);
                assert_eq!(back, req);
            }
            Err(e) => {
                assert!(
                    scoped || delta,
                    "a v5-representable request must encode at v5: {req:?}"
                );
                assert!(format!("{e:#}").contains("needs proto v6"), "{e:#}");
            }
        }
    });
}

/// Every request variant survives encode → frame → read-frame → decode
/// unchanged — the client half of the wire contract (INVARIANTS.md:
/// "Frame round-trip").
#[test]
fn prop_request_frames_round_trip() {
    property("request frame round-trip", 300, |g| {
        let req = random_request(g);
        // Payload round-trip…
        let payload = proto::encode_request(&req);
        assert_eq!(proto::decode_request(&payload).unwrap(), req);
        // …and through the length-prefixed framing layer.
        let mut wire = Vec::new();
        proto::write_frame(&mut wire, &payload).unwrap();
        let read = proto::read_frame(&mut &wire[..]).unwrap().expect("one frame");
        assert_eq!(read, payload);
    });
}

/// Every response variant survives encode → frame → read-frame → decode
/// unchanged — the server half of the wire contract.
#[test]
fn prop_response_frames_round_trip() {
    property("response frame round-trip", 300, |g| {
        let resp = random_response(g);
        let payload = proto::encode_response(&resp);
        assert_eq!(proto::decode_response(&payload).unwrap(), resp);
        let mut wire = Vec::new();
        proto::write_frame(&mut wire, &payload).unwrap();
        let read = proto::read_frame(&mut &wire[..]).unwrap().expect("one frame");
        assert_eq!(read, payload);
    });
}

// -------------------------------------------------------------- aggregation

/// One `.qsk` delta payload, the shape an aggregator flushes upstream.
fn delta_frame(meta: &SketchMeta, pool: &PooledSketch, label: &str) -> Vec<u8> {
    let prov = [ShardRecord {
        label: label.into(),
        rows: pool.count(),
    }];
    let mut bytes = Vec::new();
    write_sketch_to(&mut bytes, meta, pool, &prov).unwrap();
    bytes
}

/// I-20 + I-21 together: a random aggregation tree — batches pushed
/// directly to the root, batches flushed as deltas by an edge aggregator,
/// and batches routed through a two-level edge → mid → root chain — pools
/// to the bitwise-identical sketch as flat offline pooling of the same
/// batches, even with replayed and stale deltas interleaved at every
/// level (the idempotency gates drop them, so nothing double-counts).
#[test]
fn prop_aggregator_trees_equal_flat_pooling_with_replays() {
    property("aggregator tree == flat pooling", 10, |g| {
        let dim = g.usize_in(1, 5);
        let m = g.usize_in(1, 40);
        let sigma = g.f64_in(0.5, 2.0);
        let seed = g.rng().next_u64();
        let spec = qckm::method::MethodSpec::parse("qckm").unwrap();
        // The operator draw is a pure function of its parameters, so
        // every node in the tree — and the offline reference — holds the
        // identical operator, exactly as shared spec files guarantee in
        // deployment.
        let draw = || qckm::stream::draw_operator(&spec, FrequencyLaw::AdaptedRadius, m, dim, sigma, seed);
        let op = draw();
        let meta = SketchMeta::for_operator(&op, &spec, seed);
        let root = SketchService::new(draw(), meta.clone(), ServiceConfig::default());
        let mid = SketchService::new(draw(), meta.clone(), ServiceConfig::default());

        let (inst_edge, inst_mid) = (g.rng().next_u64(), g.rng().next_u64());
        let (mut seq_edge, mut seq_mid) = (0u64, 0u64);
        let mut edge_flushes: Vec<(u64, Vec<u8>)> = Vec::new();
        let mut want = PooledSketch::new(op.sketch_len());
        let batches = g.usize_in(1, 6);
        for i in 0..batches {
            let rows = g.usize_in(1, 30);
            let x = Mat::from_fn(rows, dim, |_, _| g.gaussian());
            op.sketch_into(&x, &mut want);
            let mut partial = PooledSketch::new(op.sketch_len());
            op.sketch_into(&x, &mut partial);
            match g.usize_in(0, 2) {
                // Straight to the root, like any ordinary pusher.
                0 => {
                    root.ingest(&format!("direct-{i}"), &x).unwrap();
                }
                // Through the edge aggregator: one delta per batch.
                1 => {
                    seq_edge += 1;
                    let bytes = delta_frame(&meta, &partial, "edge-1");
                    let (merged, _) =
                        root.ingest_delta("edge-1", inst_edge, seq_edge, &bytes).unwrap();
                    assert!(merged, "fresh delta seq {seq_edge} must merge");
                    if g.bool() {
                        // At-least-once replay (lost ack): dropped.
                        let (merged, _) =
                            root.ingest_delta("edge-1", inst_edge, seq_edge, &bytes).unwrap();
                        assert!(!merged, "replayed delta seq {seq_edge} must drop");
                    }
                    edge_flushes.push((seq_edge, bytes));
                }
                // Two levels: edge-2 → mid, mid flushes to the root below.
                _ => {
                    seq_mid += 1;
                    let bytes = delta_frame(&meta, &partial, "edge-2");
                    let (merged, _) =
                        mid.ingest_delta("edge-2", inst_mid, seq_mid, &bytes).unwrap();
                    assert!(merged);
                    if g.bool() {
                        let (merged, _) =
                            mid.ingest_delta("edge-2", inst_mid, seq_mid, &bytes).unwrap();
                        assert!(!merged, "mid-level gate must drop the replay");
                    }
                }
            }
        }

        // The mid aggregator drains everything it pooled as one delta.
        let pooled = mid.merge_window(0).pool;
        if pooled.count() > 0 {
            let bytes = delta_frame(&meta, &pooled, "mid");
            let (merged, _) = root.ingest_delta("mid", inst_mid, 1, &bytes).unwrap();
            assert!(merged);
            if g.bool() {
                let (merged, _) = root.ingest_delta("mid", inst_mid, 1, &bytes).unwrap();
                assert!(!merged, "the mid flush replay must drop");
            }
        }
        // A stale out-of-order re-send from the edge's past: dropped.
        if !edge_flushes.is_empty() {
            let (seq, bytes) = &edge_flushes[g.usize_in(0, edge_flushes.len() - 1)];
            let (merged, _) = root.ingest_delta("edge-1", inst_edge, *seq, bytes).unwrap();
            assert!(!merged, "stale seq {seq} must drop after seq {seq_edge}");
        }
        // An edge restart: new instance, sequence restarts, data merges —
        // a restarted aggregator begins empty, so its stream is new.
        if g.bool() {
            let rows = g.usize_in(1, 10);
            let x = Mat::from_fn(rows, dim, |_, _| g.gaussian());
            op.sketch_into(&x, &mut want);
            let mut partial = PooledSketch::new(op.sketch_len());
            op.sketch_into(&x, &mut partial);
            let bytes = delta_frame(&meta, &partial, "edge-1");
            let (merged, _) = root
                .ingest_delta("edge-1", inst_edge.wrapping_add(1), 1, &bytes)
                .unwrap();
            assert!(merged, "a restarted instance must merge from seq 1");
        }

        let got = root.merge_window(0).pool;
        assert_eq!(got.count(), want.count(), "row conservation across the tree");
        assert_eq!(got.sum(), want.sum(), "tree pooling must be bit-exact");
    });
}

// --------------------------------------------------------------------- .qsk

/// A `.qsk` serialization of any pooled sketch — header, provenance
/// records, payload, checksum — reads back to the identical meta, pool,
/// and provenance (INVARIANTS.md: ".qsk round-trip").
#[test]
fn prop_qsk_wire_round_trips_with_provenance() {
    property("qsk wire round-trip with provenance", 30, |g| {
        let op = random_operator(g, true);
        let rows = g.usize_in(1, 80);
        let x = Mat::from_fn(rows, op.dim(), |_, _| g.gaussian());
        let mut pool = PooledSketch::new(op.sketch_len());
        op.sketch_into(&x, &mut pool);
        let spec = qckm::method::MethodSpec::parse("qckm").unwrap();
        let meta = SketchMeta::for_operator(&op, &spec, g.seed);
        let prov: Vec<ShardRecord> = (0..g.usize_in(0, 4))
            .map(|i| ShardRecord {
                label: format!("e{i}/{}", ascii_label(g, 1, 12)),
                rows: g.rng().next_u64() >> 40,
            })
            .collect();

        let mut bytes = Vec::new();
        write_sketch_to(&mut bytes, &meta, &pool, &prov).unwrap();
        let mut cursor = &bytes[..];
        let (meta2, pool2, prov2) = read_sketch_from(&mut cursor, "prop").unwrap();
        assert!(cursor.is_empty(), "must consume exactly the sketch bytes");
        assert_eq!(meta2, meta);
        assert_eq!(pool2.count(), pool.count());
        assert_eq!(pool2.sum(), pool.sum());
        assert_eq!(prov2, prov);
    });
}

/// The pool fingerprint (the heart of the centroid-cache key and the
/// `.qsk` checksum) detects every single-bit change to the pooled sums
/// and every count change (INVARIANTS.md: "Fingerprint soundness").
#[test]
fn prop_pool_fingerprint_detects_any_bit_change() {
    property("pool fingerprint sensitivity", 60, |g| {
        let op = random_operator(g, true);
        let rows = g.usize_in(1, 60);
        let x = Mat::from_fn(rows, op.dim(), |_, _| g.gaussian());
        let mut pool = PooledSketch::new(op.sketch_len());
        op.sketch_into(&x, &mut pool);
        let base = pool_fingerprint(&pool);
        // Deterministic: recomputing never drifts.
        assert_eq!(pool_fingerprint(&pool), base);

        // Flip one random bit of one random sum slot.
        let mut sum = pool.sum().to_vec();
        let slot = g.usize_in(0, sum.len() - 1);
        let bit = g.usize_in(0, 63);
        sum[slot] = f64::from_bits(sum[slot].to_bits() ^ (1u64 << bit));
        let tampered = PooledSketch::from_raw(sum, pool.count());
        assert_ne!(
            pool_fingerprint(&tampered),
            base,
            "flipping bit {bit} of slot {slot} must change the fingerprint"
        );

        // Changing only the count must also change it.
        let recount = PooledSketch::from_raw(pool.sum().to_vec(), pool.count() + 1);
        assert_ne!(pool_fingerprint(&recount), base);
    });
}

// ---------------------------------------------------------------- decoders

/// Every canonical decoder-spec string re-parses to an equal spec with the
/// same canonical form — the grammar round-trip contract the server
/// protocol and the centroid-cache key rely on. Case and whitespace never
/// change the resolved spec, and param order canonicalizes.
#[test]
fn prop_decoder_specs_round_trip() {
    use qckm::decoder::DecoderSpec;
    property("decoder spec round-trip", 200, |g| {
        let spec = match g.usize_in(0, 4) {
            0 => DecoderSpec::parse("clompr").unwrap(),
            1 => {
                let r = g.usize_in(1, 9);
                DecoderSpec::parse(&format!("clompr:restarts={r}")).unwrap()
            }
            2 => {
                let r = g.usize_in(1, 9);
                let p = g.usize_in(1, 4);
                // Params in either order canonicalize to registry order.
                let s = if g.bool() {
                    format!("clompr:restarts={r},replacements={p}")
                } else {
                    format!("clompr:replacements={p},restarts={r}")
                };
                let spec = DecoderSpec::parse(&s).unwrap();
                assert_eq!(
                    spec.canonical(),
                    format!("clompr:restarts={r},replacements={p}")
                );
                spec
            }
            3 => DecoderSpec::parse("hier").unwrap(),
            _ => {
                let r = g.usize_in(1, 9);
                DecoderSpec::parse(&format!("hier:restarts={r}")).unwrap()
            }
        };
        let reparsed = DecoderSpec::parse(spec.canonical()).unwrap();
        assert_eq!(reparsed, spec);
        assert_eq!(reparsed.canonical(), spec.canonical());
        assert_eq!(reparsed.display_name(), spec.display_name());
        let shouted = spec.canonical().to_ascii_uppercase();
        assert_eq!(DecoderSpec::parse(&format!(" {shouted} ")).unwrap(), spec);
    });
}

/// Random junk never parses silently: either it is one of the known
/// decoder grammars or the error names the valid decoders (mirroring the
/// method-registry contract).
#[test]
fn prop_junk_decoder_specs_error_with_registry_list() {
    use qckm::decoder::DecoderSpec;
    property("junk decoder specs", 200, |g| {
        let len = g.usize_in(1, 12);
        let junk: String = (0..len)
            .map(|_| (b'a' + g.usize_in(0, 25) as u8) as char)
            .collect();
        if let Err(e) = DecoderSpec::parse(&junk) {
            let msg = format!("{e:#}");
            assert!(
                msg.contains("valid decoders") || msg.contains("parameter"),
                "unhelpful error for '{junk}': {msg}"
            );
        }
        // Junk params on a valid family are always rejected, actionably.
        if junk != "restarts" && junk != "replacements" {
            let e = DecoderSpec::parse(&format!("clompr:{junk}=1")).unwrap_err();
            let msg = format!("{e:#}");
            assert!(
                msg.contains("does not accept") || msg.contains("accepted"),
                "unhelpful param error for '{junk}': {msg}"
            );
        }
    });
}
