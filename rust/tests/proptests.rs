//! Property-based tests (via `qckm::testkit`) over the system's core
//! invariants: sketch linearity/merging, bit-packing exactness, coordinator
//! routing/batching, decoder feasibility, NNLS KKT, metrics ranges.

use qckm::coordinator::{run_pipeline, PipelineConfig, SampleSource, WireFormat};
use qckm::frequency::{DrawnFrequencies, FrequencyLaw};
use qckm::linalg::Mat;
use qckm::metrics::adjusted_rand_index;
use qckm::optim::nnls;
use qckm::parallel::Parallelism;
use qckm::rng::Rng;
use qckm::sketch::{BitAggregator, PooledSketch, SketchOperator};
use qckm::testkit::{property, Gen};
use std::sync::Arc;

fn random_operator(g: &mut Gen, quantized: bool) -> SketchOperator {
    let n = g.usize_in(1, 8);
    let m = g.usize_in(1, 60);
    let law = if g.bool() {
        FrequencyLaw::Gaussian
    } else {
        FrequencyLaw::AdaptedRadius
    };
    let sigma = g.f64_in(0.3, 3.0);
    let freqs = DrawnFrequencies::draw(law, n, m, sigma, g.rng());
    if quantized {
        SketchOperator::quantized(freqs)
    } else {
        SketchOperator::new(freqs, Arc::new(qckm::signature::Cosine))
    }
}

#[test]
fn prop_sketch_is_linear_under_any_split() {
    property("sketch linearity", 40, |g| {
        let quantized = g.bool();
        let op = random_operator(g, quantized);
        let rows = g.usize_in(2, 120);
        let x = Mat::from_fn(rows, op.dim(), |_, _| g.gaussian());
        let split = g.usize_in(1, rows - 1);
        let a = x.select_rows(&(0..split).collect::<Vec<_>>());
        let b = x.select_rows(&(split..rows).collect::<Vec<_>>());
        let mut pa = PooledSketch::new(op.sketch_len());
        let mut pb = PooledSketch::new(op.sketch_len());
        op.sketch_into(&a, &mut pa);
        op.sketch_into(&b, &mut pb);
        pa.merge(&pb);
        let whole = op.sketch_dataset(&x);
        for (u, v) in pa.mean().iter().zip(&whole) {
            assert!((u - v).abs() < 1e-9, "split at {split} of {rows}");
        }
    });
}

#[test]
fn prop_bit_packing_round_trips_and_pools_exactly() {
    property("bit packing exactness", 40, |g| {
        let op = random_operator(g, true);
        let rows = g.usize_in(1, 80);
        let x = Mat::from_fn(rows, op.dim(), |_, _| 2.0 * g.gaussian());
        let mut agg = BitAggregator::new(op.sketch_len());
        for i in 0..rows {
            let bits = op.encode_point_bits(x.row(i));
            assert_eq!(bits.to_dense(), op.encode_point(x.row(i)));
            agg.add(&bits);
        }
        let dense = op.sketch_dataset(&x);
        for (u, v) in agg.mean().iter().zip(&dense) {
            assert!((u - v).abs() < 1e-12);
        }
    });
}

#[test]
fn prop_pipeline_invariant_to_workers_batch_queue() {
    property("pipeline routing/batching invariance", 15, |g| {
        let op = random_operator(g, true);
        let rows = g.usize_in(1, 300);
        let x = Arc::new(Mat::from_fn(rows, op.dim(), |_, _| g.gaussian()));
        let reference = op.sketch_dataset(&x);
        let cfg = PipelineConfig {
            workers: g.usize_in(1, 9),
            batch_size: g.usize_in(1, 50),
            queue_capacity: g.usize_in(1, 8),
            wire: WireFormat::PackedBits,
        };
        let rep = run_pipeline(&op, &SampleSource::Shared(x.clone()), &cfg, g.seed);
        assert_eq!(rep.samples, rows as u64, "cfg {cfg:?}");
        assert_eq!(
            rep.per_worker.iter().sum::<u64>(),
            rows as u64,
            "sharding must cover exactly"
        );
        for (u, v) in rep.sketch.iter().zip(&reference) {
            assert!((u - v).abs() < 1e-12, "cfg {cfg:?}");
        }
    });
}

#[test]
fn prop_parallel_sketch_equals_serial_bit_for_bit() {
    property("parallel sketch == serial", 25, |g| {
        let quantized = g.bool();
        let op = random_operator(g, quantized);
        let rows = g.usize_in(1, 500);
        let x = Mat::from_fn(rows, op.dim(), |_, _| g.gaussian());
        let serial = op.sketch_dataset(&x);
        let threads = g.usize_in(1, 8);
        let par = Parallelism::fixed(threads);
        // Whole-dataset mean and the accumulating entry point, both exact.
        assert_eq!(op.sketch_dataset_par(&x, &par), serial, "threads {threads}");
        let mut pool = PooledSketch::new(op.sketch_len());
        op.sketch_into_par(&x, &mut pool, &par);
        assert_eq!(pool.count(), rows as u64);
        assert_eq!(pool.mean(), serial, "sketch_into_par (threads {threads})");
    });
}

#[test]
fn prop_jtv_from_atom_matches_fused_kernel_and_finite_differences() {
    property("jtv_from_atom gradients", 40, |g| {
        let quantized = g.bool();
        let op = random_operator(g, quantized);
        let c = g.vec_gaussian(op.dim());
        let v = g.vec_gaussian(op.sketch_len());
        // Trig-free JᵀV from a precomputed atom vs the fused sincos kernel.
        let mut g_fused = vec![0.0; op.dim()];
        let atom = op.atom_and_jtv(&c, &v, &mut g_fused);
        let mut g_from_atom = vec![0.0; op.dim()];
        op.jtv_from_atom(&atom, &v, &mut g_from_atom);
        for (a, b) in g_fused.iter().zip(&g_from_atom) {
            assert!(
                (a - b).abs() <= 1e-9 * (1.0 + a.abs()),
                "fused {a} vs from-atom {b}"
            );
        }
        // Both must be the true gradient of c ↦ ⟨a(c), v⟩ (central FD).
        let dir = g.vec_gaussian(op.dim());
        let h = 1e-6;
        let cp: Vec<f64> = c.iter().zip(&dir).map(|(a, d)| a + h * d).collect();
        let cm: Vec<f64> = c.iter().zip(&dir).map(|(a, d)| a - h * d).collect();
        let fd = (qckm::linalg::dot(&op.atom(&cp), &v) - qckm::linalg::dot(&op.atom(&cm), &v))
            / (2.0 * h);
        let an = qckm::linalg::dot(&g_from_atom, &dir);
        assert!(
            (fd - an).abs() < 1e-4 * (1.0 + fd.abs()),
            "directional derivative {an} vs fd {fd}"
        );
    });
}

#[test]
fn prop_atom_norm_constant_and_jacobian_consistent() {
    property("atom norm + jacobian", 30, |g| {
        let op = random_operator(g, true);
        let c = g.vec_gaussian(op.dim());
        let a = op.atom(&c);
        let want = op.atom_norm();
        let got = qckm::linalg::norm2(&a);
        assert!((got - want).abs() < 1e-9 * want.max(1.0));
        // Directional derivative check of the fused JᵀV kernel.
        let v = g.vec_gaussian(op.sketch_len());
        let mut grad = vec![0.0; op.dim()];
        let _ = op.atom_and_jtv(&c, &v, &mut grad);
        let dir = g.vec_gaussian(op.dim());
        let h = 1e-6;
        let cp: Vec<f64> = c.iter().zip(&dir).map(|(a, d)| a + h * d).collect();
        let cm: Vec<f64> = c.iter().zip(&dir).map(|(a, d)| a - h * d).collect();
        let fd = (qckm::linalg::dot(&op.atom(&cp), &v) - qckm::linalg::dot(&op.atom(&cm), &v))
            / (2.0 * h);
        let an = qckm::linalg::dot(&grad, &dir);
        assert!(
            (fd - an).abs() < 1e-4 * (1.0 + fd.abs()),
            "directional derivative {an} vs fd {fd}"
        );
    });
}

#[test]
fn prop_nnls_kkt_on_random_problems() {
    property("nnls kkt", 40, |g| {
        let m = g.usize_in(4, 60);
        let n = g.usize_in(1, 8.min(m));
        let a = Mat::from_fn(m, n, |_, _| g.gaussian());
        let b = g.vec_gaussian(m);
        let x = nnls(&a, &b);
        assert!(x.iter().all(|&v| v >= 0.0));
        let r = qckm::linalg::sub(&b, &qckm::linalg::matvec(&a, &x));
        let w = qckm::linalg::matvec_t(&a, &r);
        for j in 0..n {
            if x[j] > 1e-9 {
                assert!(w[j].abs() < 1e-5, "stationarity w[{j}]={}", w[j]);
            } else {
                assert!(w[j] < 1e-5, "dual feasibility w[{j}]={}", w[j]);
            }
        }
    });
}

#[test]
fn prop_ari_bounds_and_permutation_invariance() {
    property("ari invariances", 40, |g| {
        let n = g.usize_in(2, 400);
        let k = g.usize_in(1, 6);
        let a: Vec<usize> = (0..n).map(|_| g.usize_in(0, k - 1)).collect();
        let b: Vec<usize> = (0..n).map(|_| g.usize_in(0, k - 1)).collect();
        let ari = adjusted_rand_index(&a, &b);
        assert!((-1.0..=1.0).contains(&ari));
        assert!((adjusted_rand_index(&a, &a) - 1.0).abs() < 1e-12);
        // Permute b's labels: ARI unchanged.
        let perm: Vec<usize> = {
            let mut p: Vec<usize> = (0..k).collect();
            g.rng().shuffle(&mut p);
            p
        };
        let b2: Vec<usize> = b.iter().map(|&l| perm[l]).collect();
        assert!((adjusted_rand_index(&a, &b2) - ari).abs() < 1e-12);
    });
}

#[test]
fn prop_decoder_output_always_feasible() {
    property("decoder feasibility", 8, |g| {
        let op = random_operator(g, true);
        let k = g.usize_in(1, 3);
        let rows = g.usize_in(50, 400);
        let x = Mat::from_fn(rows, op.dim(), |_, _| g.gaussian());
        let z = op.sketch_dataset(&x);
        let (lo, hi) = qckm::linalg::bounding_box(&x);
        let mut rng = Rng::new(g.seed);
        let sol = qckm::clompr::ClOmpr::new(&op, k)
            .with_bounds(lo.clone(), hi.clone())
            .run(&z, &mut rng);
        assert_eq!(sol.centroids.rows(), k);
        assert_eq!(sol.weights.len(), k);
        assert!((sol.weights.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(sol.weights.iter().all(|&w| w >= 0.0));
        for c in 0..k {
            for (j, &v) in sol.centroids.row(c).iter().enumerate() {
                assert!(
                    v >= lo[j] - 1e-9 && v <= hi[j] + 1e-9,
                    "centroid escapes the box"
                );
            }
        }
        assert!(sol.objective.is_finite());
    });
}

/// Merging shard pools in ANY order must be exact for integer-valued
/// contributions (the ±1 quantizer): float addition of small integers
/// commutes, which is what lets the live server merge shard accumulators
/// in shard-key order — whatever order pushes arrived in — and still
/// reproduce the offline pipeline bit-for-bit.
#[test]
fn prop_pooled_merge_is_order_invariant_for_integer_sums() {
    property("pooled merge order invariance (quantized)", 30, |g| {
        let op = random_operator(g, true);
        let shards = g.usize_in(2, 6);
        let pools: Vec<PooledSketch> = (0..shards)
            .map(|_| {
                let rows = g.usize_in(1, 60);
                let x = Mat::from_fn(rows, op.dim(), |_, _| g.gaussian());
                let mut pool = PooledSketch::new(op.sketch_len());
                op.sketch_into(&x, &mut pool);
                pool
            })
            .collect();
        // Identity order vs a random permutation (Fisher–Yates).
        let mut order: Vec<usize> = (0..shards).collect();
        for i in (1..shards).rev() {
            order.swap(i, g.usize_in(0, i));
        }
        let mut forward = PooledSketch::new(op.sketch_len());
        for p in &pools {
            forward.merge(p);
        }
        let mut permuted = PooledSketch::new(op.sketch_len());
        for &i in &order {
            permuted.merge(&pools[i]);
        }
        assert_eq!(permuted.count(), forward.count());
        assert_eq!(
            permuted.sum(),
            forward.sum(),
            "quantized pools must merge exactly in any order ({order:?})"
        );
    });
}

/// BitAggregator merging is order- AND grouping-invariant (integer
/// one-counts), and its (sum, count) export always matches pooling the
/// same contributions densely.
#[test]
fn prop_bit_aggregator_merge_is_order_and_grouping_invariant() {
    property("bit aggregator merge invariance", 30, |g| {
        let op = random_operator(g, true);
        let parts = g.usize_in(2, 5);
        let aggs: Vec<BitAggregator> = (0..parts)
            .map(|_| {
                let rows = g.usize_in(1, 40);
                let mut agg = BitAggregator::new(op.sketch_len());
                let mut dense = PooledSketch::new(op.sketch_len());
                for _ in 0..rows {
                    let x = g.vec_gaussian(op.dim());
                    let bits = op.encode_point_bits(&x);
                    dense.add(&bits.to_dense());
                    agg.add(&bits);
                }
                // Exported (sum, count) == dense pooling, bit for bit.
                let (sum, count) = agg.to_sum();
                assert_eq!(sum, dense.sum());
                assert_eq!(count, dense.count());
                agg
            })
            .collect();
        // Forward fold vs reverse fold vs a two-level (pairwise) grouping.
        let fold = |order: &mut dyn Iterator<Item = &BitAggregator>| {
            let mut acc = BitAggregator::new(op.sketch_len());
            for a in order {
                acc.merge(a);
            }
            acc
        };
        let forward = fold(&mut aggs.iter());
        let reverse = fold(&mut aggs.iter().rev());
        let mut grouped = BitAggregator::new(op.sketch_len());
        for pair in aggs.chunks(2) {
            let sub = fold(&mut pair.iter());
            grouped.merge(&sub);
        }
        assert_eq!(forward.count(), reverse.count());
        assert_eq!(forward.mean(), reverse.mean());
        assert_eq!(forward.to_sum(), reverse.to_sum());
        assert_eq!(forward.to_sum(), grouped.to_sum());
    });
}

// ---------------------------------------------------------------- decoders

/// Every canonical decoder-spec string re-parses to an equal spec with the
/// same canonical form — the grammar round-trip contract the server
/// protocol and the centroid-cache key rely on. Case and whitespace never
/// change the resolved spec, and param order canonicalizes.
#[test]
fn prop_decoder_specs_round_trip() {
    use qckm::decoder::DecoderSpec;
    property("decoder spec round-trip", 200, |g| {
        let spec = match g.usize_in(0, 4) {
            0 => DecoderSpec::parse("clompr").unwrap(),
            1 => {
                let r = g.usize_in(1, 9);
                DecoderSpec::parse(&format!("clompr:restarts={r}")).unwrap()
            }
            2 => {
                let r = g.usize_in(1, 9);
                let p = g.usize_in(1, 4);
                // Params in either order canonicalize to registry order.
                let s = if g.bool() {
                    format!("clompr:restarts={r},replacements={p}")
                } else {
                    format!("clompr:replacements={p},restarts={r}")
                };
                let spec = DecoderSpec::parse(&s).unwrap();
                assert_eq!(
                    spec.canonical(),
                    format!("clompr:restarts={r},replacements={p}")
                );
                spec
            }
            3 => DecoderSpec::parse("hier").unwrap(),
            _ => {
                let r = g.usize_in(1, 9);
                DecoderSpec::parse(&format!("hier:restarts={r}")).unwrap()
            }
        };
        let reparsed = DecoderSpec::parse(spec.canonical()).unwrap();
        assert_eq!(reparsed, spec);
        assert_eq!(reparsed.canonical(), spec.canonical());
        assert_eq!(reparsed.display_name(), spec.display_name());
        let shouted = spec.canonical().to_ascii_uppercase();
        assert_eq!(DecoderSpec::parse(&format!(" {shouted} ")).unwrap(), spec);
    });
}

/// Random junk never parses silently: either it is one of the known
/// decoder grammars or the error names the valid decoders (mirroring the
/// method-registry contract).
#[test]
fn prop_junk_decoder_specs_error_with_registry_list() {
    use qckm::decoder::DecoderSpec;
    property("junk decoder specs", 200, |g| {
        let len = g.usize_in(1, 12);
        let junk: String = (0..len)
            .map(|_| (b'a' + g.usize_in(0, 25) as u8) as char)
            .collect();
        if let Err(e) = DecoderSpec::parse(&junk) {
            let msg = format!("{e:#}");
            assert!(
                msg.contains("valid decoders") || msg.contains("parameter"),
                "unhelpful error for '{junk}': {msg}"
            );
        }
        // Junk params on a valid family are always rejected, actionably.
        if junk != "restarts" && junk != "replacements" {
            let e = DecoderSpec::parse(&format!("clompr:{junk}=1")).unwrap_err();
            let msg = format!("{e:#}");
            assert!(
                msg.contains("does not accept") || msg.contains("accepted"),
                "unhelpful param error for '{junk}': {msg}"
            );
        }
    });
}
