//! End-to-end test of the online sketch service, driven through the real
//! binary (`CARGO_BIN_EXE_qckm`): start `qckm serve` on an ephemeral port,
//! push two shards from two concurrent client processes, and require the
//! queried centroids to equal the offline 2-shard `sketch → merge →
//! decode` result bit-for-bit; a `.qsk` snapshot taken from the live
//! server must load and decode to the same centroids, and must be able to
//! seed a fresh server that answers identically.
//!
//! Every wait is bounded (watchdog kill + polling with deadlines), so a
//! wedged server fails the test instead of hanging CI.

use qckm::data::{gaussian_mixture_pm1, load_csv, save_csv};
use qckm::rng::Rng;
use qckm::stream::load_sketch_full;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

const DIM: usize = 5;
const K: usize = 2;

fn work_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qckm_server_e2e_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run the qckm binary to completion; panic with its stderr if it fails.
/// Returns captured stderr for output assertions.
fn qckm_ok(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_qckm"))
        .args(args)
        .output()
        .expect("spawn qckm");
    assert!(
        out.status.success(),
        "qckm {:?} failed:\n{}",
        args,
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stderr).into_owned()
}

/// Like [`qckm_ok`] but returns captured *stdout* (for `ctl stats`
/// counter assertions).
fn qckm_stdout(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_qckm"))
        .args(args)
        .output()
        .expect("spawn qckm");
    assert!(
        out.status.success(),
        "qckm {:?} failed:\n{}",
        args,
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn sketch_args<'a>(data: &'a str, out: &'a str, threads: &'a str) -> Vec<&'a str> {
    vec![
        "sketch", "--data", data, "--out", out, "--method", "qckm", "--m", "48", "--sigma",
        "1.2", "--seed", "7", "--threads", threads,
    ]
}

fn write_fixture(dir: &Path) -> (String, String) {
    let mut rng = Rng::new(1);
    let data = gaussian_mixture_pm1(3000, DIM, K, &mut rng);
    // The same uneven, chunk-unaligned split as stream_e2e.
    let shard_a = dir.join("shard_a.csv");
    let shard_b = dir.join("shard_b.csv");
    save_csv(&shard_a, &data.points.select_rows(&(0..1337).collect::<Vec<_>>())).unwrap();
    save_csv(&shard_b, &data.points.select_rows(&(1337..3000).collect::<Vec<_>>())).unwrap();
    (
        shard_a.display().to_string(),
        shard_b.display().to_string(),
    )
}

/// A running `qckm serve` child: killed on drop, watchdog-killed after a
/// hard deadline even if the test thread is stuck waiting on it.
struct Server {
    child: Arc<Mutex<Child>>,
    addr: String,
}

impl Server {
    fn start(extra_args: &[&str]) -> Server {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_qckm"));
        cmd.args(["serve", "--port", "0", "--threads", "2"])
            .args(extra_args)
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit());
        let mut child = cmd.spawn().expect("spawn qckm serve");
        let stdout = child.stdout.take().expect("serve stdout");
        let child = Arc::new(Mutex::new(child));

        // Watchdog: no matter what, the server dies within the deadline.
        let watchdog = Arc::clone(&child);
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_secs(150));
            let _ = watchdog.lock().unwrap().kill();
        });

        // The first stdout line announces the bound address.
        let mut line = String::new();
        BufReader::new(stdout)
            .read_line(&mut line)
            .expect("read LISTENING line");
        let addr = line
            .trim()
            .strip_prefix("LISTENING ")
            .unwrap_or_else(|| panic!("unexpected serve banner: {line:?}"))
            .to_string();
        Server { child, addr }
    }

    /// Hard-kill the server (the crash the retry e2e recovers from).
    fn kill(&self) {
        let mut child = self.child.lock().unwrap();
        let _ = child.kill();
        let _ = child.wait();
    }

    /// Wait for a clean exit, bounded by a deadline.
    fn wait_exit(&self) {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            if let Some(status) = self.child.lock().unwrap().try_wait().unwrap() {
                assert!(status.success(), "server exited with {status}");
                return;
            }
            assert!(Instant::now() < deadline, "server did not exit after shutdown");
            std::thread::sleep(Duration::from_millis(50));
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let mut child = self.child.lock().unwrap();
        let _ = child.kill();
        let _ = child.wait();
    }
}

#[test]
fn live_server_matches_offline_pipeline_bit_for_bit() {
    let dir = work_dir("live");
    let (shard_a, shard_b) = write_fixture(&dir);

    // --- Offline reference: the PR-2 pipeline (sketch × 2 → merge → decode).
    let a_qsk = dir.join("a.qsk").display().to_string();
    let b_qsk = dir.join("b.qsk").display().to_string();
    let merged_qsk = dir.join("merged.qsk").display().to_string();
    let c_offline = dir.join("c_offline.csv").display().to_string();
    qckm_ok(&sketch_args(&shard_a, &a_qsk, "2"));
    qckm_ok(&sketch_args(&shard_b, &b_qsk, "7"));
    qckm_ok(&["merge", "--out", &merged_qsk, &a_qsk, &b_qsk]);
    qckm_ok(&[
        "decode", "--sketch", &merged_qsk, "--k", "2", "--lo", "-2", "--hi", "2", "--out",
        &c_offline,
    ]);

    // --- Live server: same operator parameters as the offline shards.
    let server = Server::start(&[
        "--dim", "5", "--m", "48", "--method", "qckm", "--sigma", "1.2", "--seed", "7",
    ]);
    let addr = server.addr.clone();

    // Two concurrent client processes push the two shards, in uneven
    // batches that are NOT multiples of the encode chunk sizes.
    std::thread::scope(|scope| {
        for (data, shard, batch) in [(&shard_a, "a", "999"), (&shard_b, "b", "777")] {
            let addr = addr.clone();
            scope.spawn(move || {
                qckm_ok(&[
                    "push", "--addr", &addr, "--data", data, "--shard", shard, "--batch", batch,
                ]);
            });
        }
    });

    // --- Query: the live centroids are bit-for-bit the offline centroids.
    let c_live = dir.join("c_live.csv").display().to_string();
    qckm_ok(&[
        "query", "--addr", &addr, "--k", "2", "--lo", "-2", "--hi", "2", "--out", &c_live,
    ]);
    let offline = load_csv(Path::new(&c_offline)).unwrap();
    let live = load_csv(Path::new(&c_live)).unwrap();
    assert_eq!(offline.shape(), (K, DIM));
    assert_eq!(
        offline.as_slice(),
        live.as_slice(),
        "live centroids must equal the offline sketch → merge → decode exactly"
    );

    // A repeated query is served from the centroid cache, identically.
    let c_cached = dir.join("c_cached.csv").display().to_string();
    let err = qckm_ok(&[
        "query", "--addr", &addr, "--k", "2", "--lo", "-2", "--hi", "2", "--out", &c_cached,
    ]);
    assert!(err.contains("[cached]"), "second query should hit the cache: {err}");
    assert_eq!(load_csv(Path::new(&c_cached)).unwrap().as_slice(), live.as_slice());

    // A different --decoder on the *unchanged* window must be a cache
    // miss: the centroid cache keys on the decoder spec, so hier can
    // never be served clompr's centroids.
    let c_hier = dir.join("c_hier.csv").display().to_string();
    let err = qckm_ok(&[
        "query", "--addr", &addr, "--k", "2", "--lo", "-2", "--hi", "2", "--decoder", "hier",
        "--out", &c_hier,
    ]);
    assert!(
        !err.contains("[cached]"),
        "a different decoder on an unchanged window must miss: {err}"
    );
    assert_eq!(load_csv(Path::new(&c_hier)).unwrap().shape(), (K, DIM));
    // Proven by the stats counters: 1 hit (the repeat) vs 2 misses (the
    // cold clompr decode + the hier decode), with both decoders active.
    let stats = qckm_stdout(&["ctl", "--addr", &addr, "stats"]);
    assert!(stats.contains("cache 1 hit / 2 miss"), "stats: {stats}");
    assert!(stats.contains("2 of 1024 shard slots"), "stats: {stats}");
    assert!(stats.contains("decoder 'clompr': 2 queries"), "stats: {stats}");
    assert!(stats.contains("decoder 'hier': 1 queries"), "stats: {stats}");

    // --- Metrics: `ctl metrics` prints a valid Prometheus exposition page
    // covering every layer of the serve→push→query path — request
    // counters, ingest rows, cache traffic, per-family decode timings, and
    // the parallel runner (the server shares the process-global registry).
    let page = qckm_stdout(&["ctl", "--addr", &addr, "metrics"]);
    qckm::obs::prom::validate(&page).unwrap_or_else(|e| panic!("{e:#}\npage:\n{page}"));
    for needle in [
        "qckm_requests_total{verb=\"push\"}",
        "qckm_request_seconds_bucket{verb=\"query\",le=",
        "qckm_push_rows_total 3000",
        "qckm_cache_hits_total 1",
        "qckm_cache_misses_total 2",
        "qckm_decode_seconds_count{decoder=\"clompr\"}",
        "qckm_decode_seconds_count{decoder=\"hier\"}",
        "qckm_parallel_runs_total",
        "qckm_stream_rows_total", // pre-registered at startup, 0 on this path
    ] {
        assert!(page.contains(needle), "missing `{needle}` in page:\n{page}");
    }

    // --- Snapshot: the live pool drains to a .qsk identical to the merged
    // offline shards, and decodes offline to the same centroids.
    let live_qsk = dir.join("live.qsk").display().to_string();
    qckm_ok(&["snapshot", "--addr", &addr, "--out", &live_qsk]);
    let (meta_merged, pool_merged, _) = load_sketch_full(Path::new(&merged_qsk)).unwrap();
    let (meta_live, pool_live, prov_live) = load_sketch_full(Path::new(&live_qsk)).unwrap();
    assert_eq!(meta_live, meta_merged);
    assert_eq!(pool_live.count(), 3000);
    assert_eq!(pool_live.sum(), pool_merged.sum(), "live pool deviated from offline merge");
    let labels: Vec<&str> = prov_live.iter().map(|r| r.label.as_str()).collect();
    assert_eq!(labels, ["a", "b"], "snapshot provenance in stable shard order");

    let c_snap = dir.join("c_snap.csv").display().to_string();
    qckm_ok(&[
        "decode", "--sketch", &live_qsk, "--k", "2", "--lo", "-2", "--hi", "2", "--out",
        &c_snap,
    ]);
    assert_eq!(load_csv(Path::new(&c_snap)).unwrap().as_slice(), offline.as_slice());

    // --- Stats + clean shutdown (bounded).
    qckm_ok(&["ctl", "--addr", &addr, "stats"]);
    qckm_ok(&["ctl", "--addr", &addr, "shutdown"]);
    server.wait_exit();

    // --- Resurrection: a fresh server seeded from the live snapshot
    // answers the same query identically.
    let server2 = Server::start(&["--seed-sketch", &live_qsk]);
    let c_seeded = dir.join("c_seeded.csv").display().to_string();
    qckm_ok(&[
        "query", "--addr", &server2.addr, "--k", "2", "--lo", "-2", "--hi", "2", "--out",
        &c_seeded,
    ]);
    assert_eq!(load_csv(Path::new(&c_seeded)).unwrap().as_slice(), offline.as_slice());
    qckm_ok(&["ctl", "--addr", &server2.addr, "shutdown"]);
    server2.wait_exit();
}

/// A parameterized method (`qckm:bits=2`, the multi-bit staircase) through
/// the *live* path: serve → push (two concurrent clients, each shard in a
/// single batch so the dense floating-point fold matches the offline
/// shard fold exactly) → query, against the offline `sketch → merge →
/// decode` of the same spec — bit for bit. Also proves the protocol-level
/// method check: a push declaring a different method is refused.
#[test]
fn parameterized_method_push_query_matches_offline() {
    let dir = work_dir("param");
    let (shard_a, shard_b) = write_fixture(&dir);

    // --- Offline reference with --method qckm:bits=2.
    let sketch2 = |data: &str, out: &str, threads: &str| {
        qckm_ok(&[
            "sketch", "--data", data, "--out", out, "--method", "qckm:bits=2", "--m", "48",
            "--sigma", "1.2", "--seed", "7", "--threads", threads,
        ]);
    };
    let a_qsk = dir.join("a2.qsk").display().to_string();
    let b_qsk = dir.join("b2.qsk").display().to_string();
    let merged_qsk = dir.join("merged2.qsk").display().to_string();
    let c_offline = dir.join("c_offline2.csv").display().to_string();
    sketch2(&shard_a, &a_qsk, "2");
    sketch2(&shard_b, &b_qsk, "3");
    qckm_ok(&["merge", "--out", &merged_qsk, &a_qsk, &b_qsk]);
    qckm_ok(&[
        "decode", "--sketch", &merged_qsk, "--k", "2", "--lo", "-2", "--hi", "2", "--out",
        &c_offline,
    ]);

    // --- Live server with the same parameterized operator.
    let server = Server::start(&[
        "--dim", "5", "--m", "48", "--method", "qckm:bits=2", "--sigma", "1.2", "--seed", "7",
    ]);
    let addr = server.addr.clone();

    // Each shard in ONE push batch (> shard rows): the server-side fold of
    // the batch is then exactly the offline shard fold, so the dense sums
    // agree to the last bit. Both pushers declare the method.
    std::thread::scope(|scope| {
        for (data, shard) in [(&shard_a, "a"), (&shard_b, "b")] {
            let addr = addr.clone();
            scope.spawn(move || {
                qckm_ok(&[
                    "push", "--addr", &addr, "--data", data, "--shard", shard, "--batch",
                    "2000", "--method", "qckm:bits=2",
                ]);
            });
        }
    });

    // A push declaring the wrong method is refused by the server.
    let out = Command::new(env!("CARGO_BIN_EXE_qckm"))
        .args([
            "push", "--addr", &addr, "--data", &shard_a, "--shard", "rogue", "--method",
            "qckm",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "mismatched --method must fail");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("method mismatch"), "unexpected error: {stderr}");

    // --- Query (declaring the method) == offline decode, bit for bit.
    let c_live = dir.join("c_live2.csv").display().to_string();
    qckm_ok(&[
        "query", "--addr", &addr, "--k", "2", "--lo", "-2", "--hi", "2", "--method",
        "qckm:bits=2", "--out", &c_live,
    ]);
    let offline = load_csv(Path::new(&c_offline)).unwrap();
    let live = load_csv(Path::new(&c_live)).unwrap();
    assert_eq!(offline.shape(), (K, DIM));
    assert_eq!(
        offline.as_slice(),
        live.as_slice(),
        "live qckm:bits=2 centroids must equal the offline pipeline exactly"
    );

    qckm_ok(&["ctl", "--addr", &addr, "shutdown"]);
    server.wait_exit();
}

/// `qckm sketch --append` (the online-update mode) must reproduce the
/// offline two-shard merge exactly: sketch shard A, append shard B into
/// the same file, and the pooled sums equal `qckm merge` of the two
/// independent shard sketches.
#[test]
fn sketch_append_equals_offline_merge() {
    let dir = work_dir("append");
    let (shard_a, shard_b) = write_fixture(&dir);
    let a_qsk = dir.join("a.qsk").display().to_string();
    let b_qsk = dir.join("b.qsk").display().to_string();
    let merged_qsk = dir.join("merged.qsk").display().to_string();
    qckm_ok(&sketch_args(&shard_a, &a_qsk, "1"));
    qckm_ok(&sketch_args(&shard_b, &b_qsk, "1"));
    qckm_ok(&["merge", "--out", &merged_qsk, &a_qsk, &b_qsk]);

    // Incremental: sketch A, then stream B into the same .qsk.
    let inc_qsk = dir.join("inc.qsk").display().to_string();
    qckm_ok(&sketch_args(&shard_a, &inc_qsk, "2"));
    qckm_ok(&[
        "sketch", "--data", &shard_b, "--append", &inc_qsk, "--threads", "3",
    ]);

    let (meta_merged, pool_merged, _) = load_sketch_full(Path::new(&merged_qsk)).unwrap();
    let (meta_inc, pool_inc, prov_inc) = load_sketch_full(Path::new(&inc_qsk)).unwrap();
    assert_eq!(meta_inc, meta_merged);
    assert_eq!(pool_inc.count(), pool_merged.count());
    assert_eq!(pool_inc.sum(), pool_merged.sum());
    assert_eq!(prov_inc.len(), 2, "append adds a provenance record");
    assert_eq!(prov_inc[1].label, "shard_b");
    assert_eq!(prov_inc[1].rows, 1663);

    // Conflicting operator flags are refused, and the file is untouched.
    let out = Command::new(env!("CARGO_BIN_EXE_qckm"))
        .args([
            "sketch", "--data", &shard_b, "--append", &inc_qsk, "--seed", "8",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "conflicting --seed must fail");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("conflicts"), "unexpected error: {stderr}");
    let (_, pool_after, _) = load_sketch_full(Path::new(&inc_qsk)).unwrap();
    assert_eq!(pool_after.sum(), pool_merged.sum(), "failed append must not modify the file");
}

/// The ROADMAP's server-hardening item: `qckm push --retry N` survives a
/// server kill-and-restart with bounded exponential backoff. Shard A is
/// pushed and snapshotted, the server is hard-killed, a retrying pusher
/// for shard B starts while the port is dead, and a fresh server seeded
/// from the snapshot comes back on the same port — the pusher reconnects
/// and the final query equals the offline two-shard pipeline bit for bit.
#[test]
fn push_retries_across_server_restart() {
    let dir = work_dir("retry");
    let (shard_a, shard_b) = write_fixture(&dir);

    // Offline reference: sketch × 2 → merge → decode.
    let a_qsk = dir.join("a.qsk").display().to_string();
    let b_qsk = dir.join("b.qsk").display().to_string();
    let merged_qsk = dir.join("merged.qsk").display().to_string();
    let c_offline = dir.join("c_offline.csv").display().to_string();
    qckm_ok(&sketch_args(&shard_a, &a_qsk, "2"));
    qckm_ok(&sketch_args(&shard_b, &b_qsk, "2"));
    qckm_ok(&["merge", "--out", &merged_qsk, &a_qsk, &b_qsk]);
    qckm_ok(&[
        "decode", "--sketch", &merged_qsk, "--k", "2", "--lo", "-2", "--hi", "2", "--out",
        &c_offline,
    ]);

    // First server incarnation: ingest shard A, snapshot it for the
    // resurrection.
    let server = Server::start(&[
        "--dim", "5", "--m", "48", "--method", "qckm", "--sigma", "1.2", "--seed", "7",
    ]);
    let addr = server.addr.clone();
    let port = addr.rsplit(':').next().unwrap().to_string();
    qckm_ok(&["push", "--addr", &addr, "--data", &shard_a, "--shard", "a"]);
    let seed_qsk = dir.join("seed.qsk").display().to_string();
    qckm_ok(&["snapshot", "--addr", &addr, "--out", &seed_qsk]);

    // Let the handlers observe the clients' EOFs (passive close on the
    // server side keeps the port free of TIME_WAIT), then hard-kill.
    std::thread::sleep(Duration::from_millis(500));
    server.kill();

    // Start the retrying pusher for shard B while the server is DOWN —
    // its initial connect is refused and must back off and retry. Its
    // stderr goes to a file the test polls, so the restart below happens
    // only once backoff is *observed* (no fixed-sleep scheduling race).
    let push_log = dir.join("push_b.stderr");
    let mut pusher = Command::new(env!("CARGO_BIN_EXE_qckm"))
        .args([
            "push", "--addr", &addr, "--data", &shard_b, "--shard", "b", "--retry", "12",
        ])
        .stderr(Stdio::from(std::fs::File::create(&push_log).unwrap()))
        .spawn()
        .expect("spawn retrying pusher");
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let log = std::fs::read_to_string(&push_log).unwrap_or_default();
        if log.contains("retrying in") {
            break;
        }
        assert!(
            pusher.try_wait().unwrap().is_none(),
            "pusher exited before ever backing off:\n{log}"
        );
        assert!(Instant::now() < deadline, "pusher never started retrying:\n{log}");
        std::thread::sleep(Duration::from_millis(50));
    }

    // Second incarnation on the SAME port, seeded from the snapshot so
    // shard A's history survives the crash.
    let server2 = Server::start(&[
        "--seed-sketch", &seed_qsk, "--seed-shard", "a", "--port", &port,
    ]);
    let status = pusher.wait().expect("wait for retrying pusher");
    let push_err = std::fs::read_to_string(&push_log).unwrap_or_default();
    assert!(status.success(), "retrying push failed:\n{push_err}");
    assert!(
        push_err.contains("retrying in"),
        "the pusher never had to back off: {push_err}"
    );

    // The all-time window now pools both shards: the query equals the
    // offline two-shard pipeline exactly.
    let c_live = dir.join("c_retry.csv").display().to_string();
    qckm_ok(&[
        "query", "--addr", &server2.addr, "--k", "2", "--lo", "-2", "--hi", "2", "--out",
        &c_live,
    ]);
    let offline = load_csv(Path::new(&c_offline)).unwrap();
    let live = load_csv(Path::new(&c_live)).unwrap();
    assert_eq!(offline.shape(), (K, DIM));
    assert_eq!(
        offline.as_slice(),
        live.as_slice(),
        "post-restart centroids must equal the offline pipeline exactly"
    );

    // A mismatched method declaration still fails fast under --retry
    // (server-side refusals are not transport errors; no pointless
    // backoff loop).
    let out = Command::new(env!("CARGO_BIN_EXE_qckm"))
        .args([
            "push", "--addr", &server2.addr, "--data", &shard_a, "--shard", "rogue",
            "--method", "ckm", "--retry", "5",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "mismatched --method must fail");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("method mismatch"), "unexpected error: {stderr}");
    assert!(
        !stderr.contains("retrying in"),
        "server-side refusals must not be retried: {stderr}"
    );

    qckm_ok(&["ctl", "--addr", &server2.addr, "shutdown"]);
    server2.wait_exit();
}
