//! Stub of the `xla` crate (PJRT bindings) for environments without the
//! XLA extension shared library.
//!
//! The real crate wraps thread-affine FFI handles into the PJRT C API. This
//! build environment cannot link it, so this stub provides the exact API
//! surface `qckm::runtime::PjrtEngine` compiles against, with every
//! runtime-entry point ([`PjRtClient::cpu`] first of all) returning a clear
//! "runtime unavailable" error. Shape validation and manifest handling on
//! the Rust side run before any of these calls, so those paths — and their
//! tests — work unchanged; the PJRT e2e tests self-skip when no artifacts
//! are built.
//!
//! Swap this path dependency for the real `xla` crate to enable the AOT
//! JAX/Pallas execution path; no `qckm` source changes are required.

use std::fmt;

/// Error type mirroring the real crate's (stringly) errors.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable() -> Self {
        Self {
            msg: "XLA/PJRT runtime is not available in this build \
                  (stub crate rust/vendor/xla; link the real xla crate to enable)"
                .to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// A PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::unavailable())
    }
}

/// An XLA computation (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A compiled executable (stub: execution always fails).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// A device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// A host literal (stub).
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not succeed");
        assert!(format!("{err}").contains("not available"));
    }

    #[test]
    fn literal_plumbing_typechecks() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(l.to_tuple1().is_err());
        assert!(l.to_vec::<f32>().is_err());
        let _ = XlaComputation::from_proto(&HloModuleProto);
    }
}
