//! A minimal, offline drop-in for the slice of `anyhow` this workspace uses.
//!
//! The build environment has no crates.io access, so the real `anyhow`
//! cannot be fetched. This vendored crate implements the exact API surface
//! the `qckm` crate relies on:
//!
//! * [`Error`] — an opaque error value holding a context chain.
//! * [`Result`] — `Result<T, Error>` with a default error type parameter.
//! * [`anyhow!`] / [`bail!`] — format-style error construction / early return.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * [`Error::new`] / [`Error::downcast_ref`] — typed payloads that survive
//!   `.context(..)` wrapping, so callers can classify errors (e.g. the
//!   retry client separating server refusals from transport failures).
//!
//! Formatting follows the real crate's convention: `{}` prints the outermost
//! message, `{:#}` prints the whole `outer: inner: …` chain.

use std::any::Any;
use std::fmt;

/// An error with an optional chain of wrapped causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
    /// The typed error this link was built from (when constructed via
    /// [`Error::new`] or the `?` conversion), for [`Error::downcast_ref`].
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            msg: message.to_string(),
            source: None,
            payload: None,
        }
    }

    /// Build an error from a typed `std::error::Error`, preserving the
    /// value for [`downcast_ref`](Self::downcast_ref) (like the real
    /// crate's `Error::new`).
    pub fn new<E>(error: E) -> Self
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Self::from(error)
    }

    /// A reference to the first payload of type `E` in the context chain,
    /// outermost first — survives any number of `.context(..)` wraps.
    pub fn downcast_ref<E: 'static>(&self) -> Option<&E> {
        let mut cur = Some(self);
        while let Some(e) = cur {
            if let Some(r) = e.payload.as_ref().and_then(|p| p.downcast_ref::<E>()) {
                return Some(r);
            }
            cur = e.source.as_deref();
        }
        None
    }

    fn wrap(msg: String, source: Error) -> Self {
        Self {
            msg,
            source: Some(Box::new(source)),
            payload: None,
        }
    }

    fn fmt_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            write!(f, ": {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            self.fmt_chain(f)
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.fmt_chain(f)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes this blanket conversion coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut it = msgs.into_iter().rev();
        let mut err = Error::msg(it.next().expect("at least one message"));
        for m in it {
            err = Error::wrap(m, err);
        }
        // Keep the typed value on the outermost link so downcast_ref can
        // recover it through later `.context(..)` wraps.
        err.payload = Some(Box::new(e));
        err
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and turn `None` into an error).
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C>(self, ctx: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error (or `None`) with a lazily built context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C>(self, ctx: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::wrap(ctx.to_string(), e.into()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::wrap(f().to_string(), e.into()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, ctx: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_plain_vs_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
        assert_eq!(format!("{e:?}"), "reading config: missing file");
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<u32>.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");
        let e = anyhow!("bad value {}", 7);
        assert_eq!(format!("{e}"), "bad value 7");
        fn fails() -> Result<()> {
            bail!("broke with code {}", 3);
        }
        assert_eq!(format!("{}", fails().unwrap_err()), "broke with code 3");
    }

    #[test]
    fn with_context_is_lazy_and_chains() {
        let ok: Result<u32, std::io::Error> = Ok(5);
        let v = ok.with_context(|| panic!("must not run")).unwrap();
        assert_eq!(v, 5);
        let e = Err::<(), _>(io_err())
            .with_context(|| format!("step {}", 2))
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "step 2: missing file");
    }

    #[test]
    fn downcast_ref_survives_context_wrapping() {
        #[derive(Debug, PartialEq)]
        struct Marker(u32);
        impl fmt::Display for Marker {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "marker {}", self.0)
            }
        }
        impl std::error::Error for Marker {}

        let e = Error::new(Marker(7));
        assert_eq!(e.downcast_ref::<Marker>(), Some(&Marker(7)));
        assert!(e.downcast_ref::<std::io::Error>().is_none());

        // The payload survives context wrapping (chain walk).
        let wrapped = Err::<(), _>(e).context("outer").unwrap_err();
        assert_eq!(wrapped.downcast_ref::<Marker>(), Some(&Marker(7)));
        assert_eq!(format!("{wrapped:#}"), "outer: marker 7");

        // `?`-converted errors carry their payload too.
        let via_from: Error = io_err().into();
        assert!(via_from.downcast_ref::<std::io::Error>().is_some());

        // Plain message errors have no payload.
        assert!(anyhow!("no payload").downcast_ref::<Marker>().is_none());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("x").is_err());
    }
}
