#!/usr/bin/env bash
# Aggregation-tier end-to-end: two edge aggregators fan into one root
# server hosting two tenants, with concurrent pushers, deliberate delta
# replays (--replay on edge 1), and a kill -9 + restart of edge 2
# mid-stream. The lock: per tenant, the tree's decoded centroids are
# bit-for-bit identical to a flat single-server pipeline fed the same
# rows directly (INVARIANTS.md I-20), and the replayed deltas are
# recognized and dropped upstream (I-21). Called from CI with a hard
# `timeout`; every wait below is also bounded.
set -euo pipefail

QCKM=target/release/qckm
WORK=$(mktemp -d)
PIDS=""
trap 'kill $PIDS 2>/dev/null || true; rm -rf "$WORK"' EXIT

# --- tenant specs, shared verbatim by root, flat reference, and edges:
# sharing the file is what guarantees every node draws the same operator.
cat >"$WORK/acme.toml" <<'EOF'
dim = 3
token = "s3cret"
seed = 7
[sketch]
method = "qckm"
num_frequencies = 64
sigma = 1.0
EOF
cat >"$WORK/beta.toml" <<'EOF'
dim = 2
seed = 11
[sketch]
method = "qckm:bits=3"
num_frequencies = 48
sigma = 0.8
EOF

# --- datasets: 2-cluster gaussians, split into the parts each route takes.
python3 - "$WORK" <<'EOF'
import random, sys
work = sys.argv[1]
def gen(path, rows, dim, seed):
    random.seed(seed)
    with open(path, "w") as f:
        for i in range(rows):
            c = 0.5 if i % 2 else -0.5
            f.write(",".join(f"{random.gauss(c, 0.1):.6f}" for _ in range(dim)) + "\n")
gen(f"{work}/acme_1.csv", 300, 3, 71)  # edge 1, pusher A (concurrent)
gen(f"{work}/acme_2.csv", 300, 3, 72)  # edge 1, pusher B (concurrent)
gen(f"{work}/acme_3.csv", 200, 3, 73)  # edge 2, before the kill
gen(f"{work}/acme_4.csv", 200, 3, 74)  # edge 2, after the restart
gen(f"{work}/beta_1.csv", 150, 2, 75)  # edge 1
gen(f"{work}/beta_2.csv", 100, 2, 76)  # straight to the root
EOF

wait_listen() { # outfile errfile pid -> prints HOST:PORT
    for _ in $(seq 1 100); do
        grep -q '^LISTENING ' "$1" 2>/dev/null && break
        kill -0 "$3" 2>/dev/null || { cat "$2" >&2; return 1; }
        sleep 0.1
    done
    sed -n 's/^LISTENING //p' "$1" | head -n1
}

rows_at() { # addr tenant token -> prints the tenant's all-time row count
    "$QCKM" ctl --addr "$1" --tenant "$2" ${3:+--token "$3"} stats 2>/dev/null |
        sed -n 's/.*| \([0-9]*\) rows all-time.*/\1/p'
}

wait_rows() { # addr tenant token want
    for _ in $(seq 1 150); do
        [ "$(rows_at "$1" "$2" "$3")" = "$4" ] && return 0
        sleep 0.2
    done
    echo "tenant '$2' on $1 never reached $4 rows (have '$(rows_at "$1" "$2" "$3")')"
    return 1
}

# --- the root and the flat reference server (identical tenant specs).
"$QCKM" serve --tenant "acme=$WORK/acme.toml" --tenant "beta=$WORK/beta.toml" \
    --port 0 >"$WORK/root.out" 2>"$WORK/root.err" &
ROOT_PID=$!; PIDS="$PIDS $ROOT_PID"
"$QCKM" serve --tenant "acme=$WORK/acme.toml" --tenant "beta=$WORK/beta.toml" \
    --port 0 >"$WORK/flat.out" 2>"$WORK/flat.err" &
FLAT_PID=$!; PIDS="$PIDS $FLAT_PID"
ROOT=$(wait_listen "$WORK/root.out" "$WORK/root.err" $ROOT_PID)
FLAT=$(wait_listen "$WORK/flat.out" "$WORK/flat.err" $FLAT_PID)

# --- edge 1: both tenants, row-threshold flushes, and --replay fault
# injection (every delta is sent twice; the process aborts if the root
# ever merges the duplicate, so it doubles as an in-band assertion).
"$QCKM" aggregate --upstream "$ROOT" --agg-id edge-1 \
    --tenant "acme=$WORK/acme.toml" --tenant "beta=$WORK/beta.toml" \
    --flush-rows 256 --flush-ms 200 --replay \
    --port 0 >"$WORK/edge1.out" 2>"$WORK/edge1.err" &
EDGE1_PID=$!; PIDS="$PIDS $EDGE1_PID"
# --- edge 2: acme only, timer-driven flushes. This is the one we kill.
"$QCKM" aggregate --upstream "$ROOT" --agg-id edge-2 \
    --tenant "acme=$WORK/acme.toml" \
    --flush-ms 200 --port 0 >"$WORK/edge2.out" 2>"$WORK/edge2.err" &
EDGE2_PID=$!; PIDS="$PIDS $EDGE2_PID"
EDGE1=$(wait_listen "$WORK/edge1.out" "$WORK/edge1.err" $EDGE1_PID)
EDGE2=$(wait_listen "$WORK/edge2.out" "$WORK/edge2.err" $EDGE2_PID)

# --- concurrent pushers into edge 1, plus edge 2's pre-kill batch.
"$QCKM" push --addr "$EDGE1" --tenant acme --token s3cret --retry 8 \
    --data "$WORK/acme_1.csv" --shard pusher-a &
PUSH_A=$!
"$QCKM" push --addr "$EDGE1" --tenant acme --token s3cret --retry 8 \
    --data "$WORK/acme_2.csv" --shard pusher-b &
PUSH_B=$!
"$QCKM" push --addr "$EDGE1" --tenant beta --retry 8 --data "$WORK/beta_1.csv"
"$QCKM" push --addr "$EDGE2" --tenant acme --token s3cret --retry 8 \
    --data "$WORK/acme_3.csv"
wait $PUSH_A $PUSH_B

# Every pushed acme row (600 via edge 1, 200 via edge 2) must reach the
# root before the kill — rows still pooled at edge 2 would die with it.
wait_rows "$ROOT" acme s3cret 800

# --- kill -9 edge 2 mid-stream and restart it under the same agg-id.
# The restart gets a fresh instance nonce, so the root accepts its new
# (instance, seq=1) stream instead of dropping it below the dead
# process's high-water sequence.
kill -9 $EDGE2_PID
wait $EDGE2_PID 2>/dev/null || true
"$QCKM" aggregate --upstream "$ROOT" --agg-id edge-2 \
    --tenant "acme=$WORK/acme.toml" \
    --flush-ms 200 --port 0 >"$WORK/edge2b.out" 2>"$WORK/edge2b.err" &
EDGE2B_PID=$!; PIDS="$PIDS $EDGE2B_PID"
EDGE2B=$(wait_listen "$WORK/edge2b.out" "$WORK/edge2b.err" $EDGE2B_PID)
"$QCKM" push --addr "$EDGE2B" --tenant acme --token s3cret --retry 8 \
    --data "$WORK/acme_4.csv"
# One batch skips the tree entirely — direct pushes and deltas must pool
# into the same tenant state.
"$QCKM" push --addr "$ROOT" --tenant beta --data "$WORK/beta_2.csv"

# --- graceful shutdown drains both edges (pending + in-flight deltas).
"$QCKM" ctl --addr "$EDGE1" shutdown
"$QCKM" ctl --addr "$EDGE2B" shutdown
wait $EDGE1_PID $EDGE2B_PID
wait_rows "$ROOT" acme s3cret 1000
wait_rows "$ROOT" beta "" 250

# --- auth: a wrong token must be refused (and counted), not pooled.
if "$QCKM" push --addr "$ROOT" --tenant acme --token wrong \
    --data "$WORK/acme_1.csv" 2>/dev/null; then
    echo "a push with a bad token was accepted"; exit 1
fi
wait_rows "$ROOT" acme s3cret 1000

# --- per-tenant occupancy in ctl stats (the v6 stats block).
"$QCKM" ctl --addr "$ROOT" --tenant acme --token s3cret stats >"$WORK/stats.txt"
grep -q "tenant 'acme': 1000 rows" "$WORK/stats.txt" || {
    echo "stats is missing acme occupancy:"; cat "$WORK/stats.txt"; exit 1
}
grep -q "tenant 'beta': 250 rows" "$WORK/stats.txt" || {
    echo "stats is missing beta occupancy:"; cat "$WORK/stats.txt"; exit 1
}

# --- the root's metrics must show merged deltas, recognized replays
# (edge 1 sent every delta twice), and exactly one auth failure.
"$QCKM" ctl --addr "$ROOT" metrics >"$WORK/metrics.txt"
grep 'qckm_deltas_total' "$WORK/metrics.txt" | grep 'outcome="merged"' |
    grep -qv ' 0$' || {
    echo "no merged deltas counted:"; grep qckm_deltas "$WORK/metrics.txt"; exit 1
}
grep 'qckm_deltas_total' "$WORK/metrics.txt" | grep 'outcome="replayed"' |
    grep -qv ' 0$' || {
    echo "no replayed deltas counted:"; grep qckm_deltas "$WORK/metrics.txt" || true; exit 1
}
grep -q 'qckm_auth_failures_total{tenant="acme"} 1' "$WORK/metrics.txt" || {
    echo "auth failure counter wrong:"
    grep qckm_auth "$WORK/metrics.txt" || true; exit 1
}

# --- the flat reference: the same rows, pushed straight to one server.
for part in 1 2 3 4; do
    "$QCKM" push --addr "$FLAT" --tenant acme --token s3cret \
        --data "$WORK/acme_$part.csv"
done
"$QCKM" push --addr "$FLAT" --tenant beta --data "$WORK/beta_1.csv"
"$QCKM" push --addr "$FLAT" --tenant beta --data "$WORK/beta_2.csv"

# --- the lock: identical queries, bit-for-bit identical centroids.
for side in tree flat; do
    addr=$ROOT; [ "$side" = flat ] && addr=$FLAT
    "$QCKM" query --addr "$addr" --tenant acme --token s3cret \
        --k 2 --lo -1 --hi 1 --out "$WORK/${side}_acme.csv"
    "$QCKM" query --addr "$addr" --tenant beta \
        --k 2 --lo -1 --hi 1 --out "$WORK/${side}_beta.csv"
done
for tenant in acme beta; do
    cmp "$WORK/tree_$tenant.csv" "$WORK/flat_$tenant.csv" || {
        echo "tenant '$tenant': aggregator tree != flat server"; exit 1
    }
    echo "tenant '$tenant': tree centroids == flat centroids (bit-for-bit)"
done

# CI artifacts: the exactness evidence plus the root's telemetry.
cp "$WORK/metrics.txt" AGG_e2e_metrics.txt
cp "$WORK/stats.txt" AGG_e2e_stats.txt
for f in tree_acme tree_beta flat_acme flat_beta; do
    cp "$WORK/$f.csv" "AGG_e2e_$f.csv"
done

"$QCKM" ctl --addr "$ROOT" shutdown
"$QCKM" ctl --addr "$FLAT" shutdown
wait $ROOT_PID $FLAT_PID

echo "aggregator e2e OK"
