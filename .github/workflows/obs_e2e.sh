#!/usr/bin/env bash
# Observability end-to-end smoke: serve with structured logging on, push a
# small CSV, query, scrape `ctl metrics`, and assert both outputs are real.
# Called from CI with a hard `timeout`; every wait below is also bounded.
set -euo pipefail

QCKM=target/release/qckm
WORK=$(mktemp -d)
trap 'kill $SERVER_PID 2>/dev/null || true; rm -rf "$WORK"' EXIT

# A tiny 2-cluster dataset around ±0.5 in 3 dimensions.
python3 - "$WORK/data.csv" <<'EOF'
import random, sys
random.seed(7)
with open(sys.argv[1], "w") as f:
    for i in range(400):
        c = 0.5 if i % 2 else -0.5
        f.write(",".join(f"{random.gauss(c, 0.1):.6f}" for _ in range(3)) + "\n")
EOF

# Serve on an ephemeral port with both logging switches exercised: the
# --log-json flag and the QCKM_LOG env var (idempotent together).
QCKM_LOG=json "$QCKM" serve --log-json --dim 3 --m 64 --method qckm \
    --sigma 1.0 --seed 7 --port 0 >"$WORK/serve.out" 2>"$WORK/serve.err" &
SERVER_PID=$!

for _ in $(seq 1 100); do
    grep -q '^LISTENING ' "$WORK/serve.out" 2>/dev/null && break
    kill -0 $SERVER_PID 2>/dev/null || { cat "$WORK/serve.err"; exit 1; }
    sleep 0.1
done
ADDR=$(sed -n 's/^LISTENING //p' "$WORK/serve.out" | head -n1)
[ -n "$ADDR" ] || { echo "server never announced an address"; exit 1; }

"$QCKM" push --addr "$ADDR" --data "$WORK/data.csv" --shard ci
# A traced query: stdout (the objective + centroids) must be unaffected,
# and the span tree lands on stderr.
"$QCKM" query --addr "$ADDR" --k 2 --lo -1 --hi 1 --trace \
    --out "$WORK/centroids.csv" 2>"$WORK/query.err"
[ -s "$WORK/centroids.csv" ] || { echo "query produced no centroids"; exit 1; }
grep -q '"stage": "window_merge"' "$WORK/query.err" || {
    echo "traced query printed no span tree:"; cat "$WORK/query.err"; exit 1
}

# The scrape: non-empty, and covering server + library metric families.
"$QCKM" ctl --addr "$ADDR" metrics >"$WORK/metrics.txt"
for series in qckm_requests_total qckm_push_rows_total qckm_decode_seconds_bucket \
              qckm_build_info qckm_uptime_seconds qckm_shard_bit_balance \
              qckm_query_residual_norm; do
    grep -q "$series" "$WORK/metrics.txt" || {
        echo "metrics page is missing $series:"; cat "$WORK/metrics.txt"; exit 1
    }
done
grep -q 'qckm_push_rows_total 400' "$WORK/metrics.txt" || {
    echo "push row counter wrong:"; grep qckm_push_rows "$WORK/metrics.txt"; exit 1
}

# Scrape again and assert every counter is monotone non-decreasing across
# the two pages (the Prometheus contract a restart-free server must hold).
"$QCKM" ctl --addr "$ADDR" metrics >"$WORK/metrics2.txt"
python3 - "$WORK/metrics.txt" "$WORK/metrics2.txt" <<'EOF'
import sys

def counters(path):
    series, kind = {}, {}
    for line in open(path):
        line = line.strip()
        if line.startswith("# TYPE "):
            _, _, name, k = line.split()
            kind[name] = k
        elif line and not line.startswith("#"):
            key, value = line.rsplit(" ", 1)
            name = key.split("{")[0]
            base = name.rsplit("_bucket", 1)[0].rsplit("_sum", 1)[0].rsplit("_count", 1)[0]
            if kind.get(name) == "counter" or (kind.get(base) == "histogram" and value != "NaN"):
                series[key] = float(value)
    return series

first, second = counters(sys.argv[1]), counters(sys.argv[2])
regressed = [k for k, v in first.items() if k in second and second[k] < v]
assert not regressed, f"counters went backwards between scrapes: {regressed}"
assert len(second) >= len(first), "second scrape lost series"
print(f"counter monotonicity OK over {len(first)} series")
EOF

# The trace verb: valid JSON holding the traced query (and the traced
# batch pushes), newest first. Kept as a CI artifact for debugging.
"$QCKM" ctl --addr "$ADDR" trace --limit 10 >"$WORK/traces.json"
python3 - "$WORK/traces.json" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
traces = doc["traces"]
assert traces, "the trace ring is empty after a traced query"
verbs = [t["verb"] for t in traces]
assert "query" in verbs, f"no query trace in {verbs}"
stages = [s["stage"] for s in traces[verbs.index("query")]["spans"]]
assert "frame_decode" in stages, f"missing frame_decode root in {stages}"
print(f"validated {len(traces)} trace(s): verbs {verbs}")
EOF
cp "$WORK/traces.json" TRACE_e2e.json

"$QCKM" ctl --addr "$ADDR" shutdown
wait $SERVER_PID

# Structured logs: at least one request event, and every json line parses.
grep -q '"event":"request"' "$WORK/serve.err" || {
    echo "no structured request events in server stderr:"; cat "$WORK/serve.err"; exit 1
}
python3 - "$WORK/serve.err" <<'EOF'
import json, sys
n = 0
for line in open(sys.argv[1]):
    line = line.strip()
    if line.startswith("{"):
        json.loads(line)
        n += 1
assert n > 0, "no JSON log lines found"
print(f"validated {n} JSON log lines")
EOF

echo "observability e2e OK"
